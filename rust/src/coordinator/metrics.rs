//! Service metrics: request/batch counters, padding waste, device busy
//! time, end-to-end latency percentiles, and the paper's Gsps (eq. 3)
//! computed over the serving window.
//!
//! One [`Metrics`] sink is shared by every thread in the service (the
//! dispatcher, the batch workers, and search callers); counters are
//! relaxed atomics, latency distributions live behind short-lock
//! histograms.  [`Metrics::snapshot`] materializes a consistent-enough
//! point-in-time [`MetricsSnapshot`] for the `metrics` protocol verb and
//! the CLI's end-of-run summary; `docs/METRICS.md` documents every field
//! and who increments it.
//!
//! Four counter families:
//! * **align path** — submits/responses/rejects, batch fill and padding,
//!   device busy time, and Gsps over both busy and wall time;
//! * **search path** — per-stage cascade prune counters aggregated over
//!   all searches, plus a separate search latency histogram;
//! * **sharded executor** — shards run, shared-threshold tightenings,
//!   and per-search wall-time imbalance (recorded only by
//!   [`Metrics::on_search_sharded`], and only when the timings carry
//!   signal);
//! * **streaming session** — appends and samples ingested, delta
//!   searches served, and the incremental-vs-rebuild candidate split
//!   (how much cascading the watermark actually saved).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::obs;
use crate::search::CascadeStats;
use crate::util::stats::{gsps, LatencyHistogram};

/// Shared, thread-safe metrics sink.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    requests: AtomicU64,
    responses: AtomicU64,
    errors: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
    padded_rows: AtomicU64,
    real_rows: AtomicU64,
    /// floats processed (paper's metric: batch rows × qlen, real rows only)
    floats: AtomicU64,
    /// DP cells processed (real rows only)
    cells: AtomicU64,
    /// accumulated device execute time in microseconds
    busy_us: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    queue_time: Mutex<LatencyHistogram>,
    // ------------------------- search (top-K cascade) counters
    searches: AtomicU64,
    search_windows: AtomicU64,
    search_pruned_kim: AtomicU64,
    search_pruned_keogh: AtomicU64,
    search_dp_abandoned: AtomicU64,
    search_dp_full: AtomicU64,
    /// windows accounted without any stage running (k = 0 requests)
    search_skipped: AtomicU64,
    /// survivor batches flushed through the DP kernel (lanes executed
    /// per batch = dp_abandoned + dp_full contributions of that flush)
    search_survivor_batches: AtomicU64,
    /// envelope blocks evaluated through the LB prefilter kernel
    search_lb_blocks: AtomicU64,
    /// candidates evaluated across those LB blocks (occupancy numerator)
    search_lb_evals: AtomicU64,
    /// Keogh evaluations early-abandoned mid-sum (subset of pruned_keogh)
    search_lb_abandons: AtomicU64,
    /// windows cut because the band admitted no warping path
    search_pruned_band: AtomicU64,
    /// DP cells the band mask excluded across stage-3 flushes
    search_band_cells_skipped: AtomicU64,
    search_latency: Mutex<LatencyHistogram>,
    // ------------------------- sharded-executor counters
    searches_sharded: AtomicU64,
    search_shards: AtomicU64,
    search_tau_tightenings: AtomicU64,
    /// sum of per-search imbalance ratios in milli-units (ratio × 1000),
    /// so the mean stays exact under concurrent atomic accumulation
    search_imbalance_milli: AtomicU64,
    /// sharded searches whose timings carried signal (the imbalance
    /// mean's denominator — zero-timing searches are excluded, not
    /// counted as "perfectly even")
    search_imbalance_samples: AtomicU64,
    // ------------------------- cluster counters
    /// worker nodes attached to the cluster backend (gauge; 0 when the
    /// service runs single-node)
    cluster_nodes: AtomicU64,
    /// τ-tightening messages pushed to remote nodes mid-search
    tau_broadcasts: AtomicU64,
    /// shard chunks stolen from a slower node's deque
    shards_stolen: AtomicU64,
    // ------------------------- serving-edge counters
    /// connections currently open at the serving front end (gauge)
    conns_open: AtomicU64,
    /// frames dropped for exceeding the max-frame cap
    frames_oversized: AtomicU64,
    /// requests that arrived while the same connection already had one
    /// in flight (pipelining depth signal)
    requests_pipelined: AtomicU64,
    // ------------------------- streaming-session counters
    stream_appends: AtomicU64,
    stream_samples: AtomicU64,
    delta_searches: AtomicU64,
    /// candidates actually cascaded by delta searches
    delta_candidates_scanned: AtomicU64,
    /// candidates delta searches skipped thanks to the watermark (what a
    /// full rebuild would have re-cascaded)
    delta_candidates_skipped: AtomicU64,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: AtomicU64::new(0),
            responses: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_rows: AtomicU64::new(0),
            real_rows: AtomicU64::new(0),
            floats: AtomicU64::new(0),
            cells: AtomicU64::new(0),
            busy_us: AtomicU64::new(0),
            latency: Mutex::new(LatencyHistogram::new()),
            queue_time: Mutex::new(LatencyHistogram::new()),
            searches: AtomicU64::new(0),
            search_windows: AtomicU64::new(0),
            search_pruned_kim: AtomicU64::new(0),
            search_pruned_keogh: AtomicU64::new(0),
            search_dp_abandoned: AtomicU64::new(0),
            search_dp_full: AtomicU64::new(0),
            search_skipped: AtomicU64::new(0),
            search_survivor_batches: AtomicU64::new(0),
            search_lb_blocks: AtomicU64::new(0),
            search_lb_evals: AtomicU64::new(0),
            search_lb_abandons: AtomicU64::new(0),
            search_pruned_band: AtomicU64::new(0),
            search_band_cells_skipped: AtomicU64::new(0),
            search_latency: Mutex::new(LatencyHistogram::new()),
            searches_sharded: AtomicU64::new(0),
            search_shards: AtomicU64::new(0),
            search_tau_tightenings: AtomicU64::new(0),
            search_imbalance_milli: AtomicU64::new(0),
            search_imbalance_samples: AtomicU64::new(0),
            cluster_nodes: AtomicU64::new(0),
            tau_broadcasts: AtomicU64::new(0),
            shards_stolen: AtomicU64::new(0),
            conns_open: AtomicU64::new(0),
            frames_oversized: AtomicU64::new(0),
            requests_pipelined: AtomicU64::new(0),
            stream_appends: AtomicU64::new(0),
            stream_samples: AtomicU64::new(0),
            delta_searches: AtomicU64::new(0),
            delta_candidates_scanned: AtomicU64::new(0),
            delta_candidates_skipped: AtomicU64::new(0),
        }
    }

    /// Record one completed top-K search and its cascade counters.
    pub fn on_search(&self, latency_ms: f64, stats: &CascadeStats) {
        self.searches.fetch_add(1, Ordering::Relaxed);
        self.search_windows
            .fetch_add(stats.candidates, Ordering::Relaxed);
        self.search_pruned_kim
            .fetch_add(stats.pruned_kim, Ordering::Relaxed);
        self.search_pruned_keogh
            .fetch_add(stats.pruned_keogh, Ordering::Relaxed);
        self.search_dp_abandoned
            .fetch_add(stats.dp_abandoned, Ordering::Relaxed);
        self.search_dp_full
            .fetch_add(stats.dp_full, Ordering::Relaxed);
        self.search_skipped
            .fetch_add(stats.skipped, Ordering::Relaxed);
        self.search_survivor_batches
            .fetch_add(stats.survivor_batches, Ordering::Relaxed);
        self.search_lb_blocks
            .fetch_add(stats.lb_blocks, Ordering::Relaxed);
        self.search_lb_evals
            .fetch_add(stats.lb_evals, Ordering::Relaxed);
        self.search_lb_abandons
            .fetch_add(stats.lb_abandons, Ordering::Relaxed);
        self.search_pruned_band
            .fetch_add(stats.pruned_band, Ordering::Relaxed);
        self.search_band_cells_skipped
            .fetch_add(stats.band_cells_skipped, Ordering::Relaxed);
        self.search_latency.lock().unwrap().record_ms(latency_ms);
    }

    /// Record one completed *sharded* top-K search: the merged cascade
    /// counters plus the executor's telemetry — shards run, how often the
    /// shared τ tightened (the cross-shard pruning win), and the
    /// max/mean wall-time imbalance across shards.  `imbalance` is
    /// `None` when the shard timings carried no signal (all rounded to
    /// zero); such searches are excluded from the imbalance mean rather
    /// than read as "perfectly even".
    pub fn on_search_sharded(
        &self,
        latency_ms: f64,
        stats: &CascadeStats,
        shards: u64,
        tau_tightenings: u64,
        imbalance: Option<f64>,
    ) {
        self.on_search(latency_ms, stats);
        self.searches_sharded.fetch_add(1, Ordering::Relaxed);
        self.search_shards.fetch_add(shards, Ordering::Relaxed);
        self.search_tau_tightenings
            .fetch_add(tau_tightenings, Ordering::Relaxed);
        if let Some(r) = imbalance {
            self.search_imbalance_milli
                .fetch_add((r.max(0.0) * 1e3).round() as u64, Ordering::Relaxed);
            self.search_imbalance_samples.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record the cluster's node count once a shard backend attaches
    /// (gauge; stays 0 on a single-node service).
    pub fn set_cluster_nodes(&self, n: u64) {
        self.cluster_nodes.store(n, Ordering::Relaxed);
    }

    /// Record one completed *cluster* top-K search: the merged cascade
    /// counters plus the cluster executor's telemetry — remote shard
    /// verbs run, τ tightenings observed at the coordinator, τ
    /// broadcasts pushed to other nodes, and shard chunks stolen off a
    /// slower node's deque.  Per-shard wall times live on the worker
    /// nodes, so no imbalance sample is recorded here.
    #[allow(clippy::too_many_arguments)]
    pub fn on_search_cluster(
        &self,
        latency_ms: f64,
        stats: &CascadeStats,
        shards: u64,
        tau_tightenings: u64,
        tau_broadcasts: u64,
        shards_stolen: u64,
    ) {
        self.on_search_sharded(latency_ms, stats, shards, tau_tightenings, None);
        self.tau_broadcasts.fetch_add(tau_broadcasts, Ordering::Relaxed);
        self.shards_stolen.fetch_add(shards_stolen, Ordering::Relaxed);
    }

    /// A connection opened at the serving front end (either the blocking
    /// or the reactor edge).
    pub fn on_conn_open(&self) {
        self.conns_open.fetch_add(1, Ordering::Relaxed);
    }

    /// The matching close.  Saturating: a spurious close (e.g. a failed
    /// accept handshake counted once) clamps at zero instead of wrapping
    /// the gauge to u64::MAX.
    pub fn on_conn_close(&self) {
        let _ = self
            .conns_open
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| Some(v.saturating_sub(1)));
    }

    /// A frame exceeded the max-frame cap and was dropped.
    pub fn on_frame_oversized(&self) {
        self.frames_oversized.fetch_add(1, Ordering::Relaxed);
    }

    /// A request arrived while its connection already had at least one
    /// request in flight — the client is pipelining.
    pub fn on_pipelined_request(&self) {
        self.requests_pipelined.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one streaming append.
    pub fn on_stream_append(&self, samples: u64) {
        self.stream_appends.fetch_add(1, Ordering::Relaxed);
        self.stream_samples.fetch_add(samples, Ordering::Relaxed);
    }

    /// Record one delta (streaming) search: how many candidates the
    /// incremental pass cascaded vs skipped via the watermark.
    pub fn on_delta_search(&self, scanned: u64, skipped: u64) {
        self.delta_searches.fetch_add(1, Ordering::Relaxed);
        self.delta_candidates_scanned
            .fetch_add(scanned, Ordering::Relaxed);
        self.delta_candidates_skipped
            .fetch_add(skipped, Ordering::Relaxed);
    }

    pub fn on_submit(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn on_batch(&self, real: usize, padding: usize, qlen: usize, reflen: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.real_rows.fetch_add(real as u64, Ordering::Relaxed);
        self.padded_rows.fetch_add(padding as u64, Ordering::Relaxed);
        self.floats
            .fetch_add((real * qlen) as u64, Ordering::Relaxed);
        self.cells
            .fetch_add((real * qlen) as u64 * reflen as u64, Ordering::Relaxed);
    }

    pub fn on_execute(&self, exec_ms: f64) {
        self.busy_us
            .fetch_add((exec_ms * 1e3) as u64, Ordering::Relaxed);
    }

    pub fn on_response(&self, latency_ms: f64) {
        self.responses.fetch_add(1, Ordering::Relaxed);
        self.latency.lock().unwrap().record_ms(latency_ms);
    }

    pub fn on_queue_time(&self, ms: f64) {
        self.queue_time.lock().unwrap().record_ms(ms);
    }

    pub fn on_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency = self.latency.lock().unwrap();
        let queue = self.queue_time.lock().unwrap();
        let search_latency = self.search_latency.lock().unwrap();
        let floats = self.floats.load(Ordering::Relaxed);
        let busy_ms = self.busy_us.load(Ordering::Relaxed) as f64 / 1e3;
        let wall_ms = self.started.elapsed().as_secs_f64() * 1e3;
        // load each survivor counter once so the derived occupancy is
        // consistent with the sibling fields in the same snapshot
        let dp_abandoned = self.search_dp_abandoned.load(Ordering::Relaxed);
        let dp_full = self.search_dp_full.load(Ordering::Relaxed);
        let survivor_batches = self.search_survivor_batches.load(Ordering::Relaxed);
        // same single-load discipline for the LB occupancy pair
        let lb_blocks = self.search_lb_blocks.load(Ordering::Relaxed);
        let lb_evals = self.search_lb_evals.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            responses: self.responses.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            real_rows: self.real_rows.load(Ordering::Relaxed),
            padded_rows: self.padded_rows.load(Ordering::Relaxed),
            floats_processed: floats,
            cells: self.cells.load(Ordering::Relaxed),
            busy_ms,
            wall_ms,
            device_gsps: if busy_ms > 0.0 { gsps(floats, busy_ms) } else { 0.0 },
            offered_gsps: if wall_ms > 0.0 { gsps(floats, wall_ms) } else { 0.0 },
            latency_mean_ms: latency.mean_ms(),
            latency_p50_ms: latency.percentile_ms(50.0),
            latency_p95_ms: latency.percentile_ms(95.0),
            latency_p99_ms: latency.percentile_ms(99.0),
            queue_mean_ms: queue.mean_ms(),
            searches: self.searches.load(Ordering::Relaxed),
            search_windows: self.search_windows.load(Ordering::Relaxed),
            search_pruned_kim: self.search_pruned_kim.load(Ordering::Relaxed),
            search_pruned_keogh: self.search_pruned_keogh.load(Ordering::Relaxed),
            search_dp_abandoned: dp_abandoned,
            search_dp_full: dp_full,
            search_skipped: self.search_skipped.load(Ordering::Relaxed),
            search_survivor_batches: survivor_batches,
            search_lane_occupancy_mean: if survivor_batches == 0 {
                0.0
            } else {
                (dp_abandoned + dp_full) as f64 / survivor_batches as f64
            },
            search_lb_blocks: lb_blocks,
            search_lb_evals: lb_evals,
            search_lb_abandons: self.search_lb_abandons.load(Ordering::Relaxed),
            search_pruned_band: self.search_pruned_band.load(Ordering::Relaxed),
            search_band_cells_skipped: self.search_band_cells_skipped.load(Ordering::Relaxed),
            search_lb_block_occupancy_mean: if lb_blocks == 0 {
                0.0
            } else {
                lb_evals as f64 / lb_blocks as f64
            },
            search_latency_mean_ms: search_latency.mean_ms(),
            search_latency_p50_ms: search_latency.percentile_ms(50.0),
            search_latency_p99_ms: search_latency.percentile_ms(99.0),
            searches_sharded: self.searches_sharded.load(Ordering::Relaxed),
            search_shards: self.search_shards.load(Ordering::Relaxed),
            search_tau_tightenings: self.search_tau_tightenings.load(Ordering::Relaxed),
            search_imbalance_samples: self.search_imbalance_samples.load(Ordering::Relaxed),
            search_imbalance_mean: {
                let n = self.search_imbalance_samples.load(Ordering::Relaxed);
                if n == 0 {
                    0.0
                } else {
                    self.search_imbalance_milli.load(Ordering::Relaxed) as f64
                        / 1e3
                        / n as f64
                }
            },
            cluster_nodes: self.cluster_nodes.load(Ordering::Relaxed),
            tau_broadcasts: self.tau_broadcasts.load(Ordering::Relaxed),
            shards_stolen: self.shards_stolen.load(Ordering::Relaxed),
            conns_open: self.conns_open.load(Ordering::Relaxed),
            frames_oversized: self.frames_oversized.load(Ordering::Relaxed),
            requests_pipelined: self.requests_pipelined.load(Ordering::Relaxed),
            stream_appends: self.stream_appends.load(Ordering::Relaxed),
            stream_samples: self.stream_samples.load(Ordering::Relaxed),
            delta_searches: self.delta_searches.load(Ordering::Relaxed),
            delta_candidates_scanned: self.delta_candidates_scanned.load(Ordering::Relaxed),
            delta_candidates_skipped: self.delta_candidates_skipped.load(Ordering::Relaxed),
            stages: obs::stage_summaries(),
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Point-in-time metrics readout.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub responses: u64,
    pub errors: u64,
    pub rejected: u64,
    pub batches: u64,
    pub real_rows: u64,
    pub padded_rows: u64,
    pub floats_processed: u64,
    pub cells: u64,
    /// Device-side execute time (sum over batches).
    pub busy_ms: f64,
    /// Wall time since service start.
    pub wall_ms: f64,
    /// Paper eq. 3 over device busy time (kernel throughput).
    pub device_gsps: f64,
    /// Paper eq. 3 over wall time (offered/served throughput).
    pub offered_gsps: f64,
    pub latency_mean_ms: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    pub queue_mean_ms: f64,
    /// Top-K searches served.
    pub searches: u64,
    /// Candidate windows considered across all searches.
    pub search_windows: u64,
    /// Windows pruned by the LB_Kim stage.
    pub search_pruned_kim: u64,
    /// Windows pruned by the LB_Keogh stage.
    pub search_pruned_keogh: u64,
    /// Windows whose DP was abandoned mid-recurrence.
    pub search_dp_abandoned: u64,
    /// Windows that ran a full exact DP.
    pub search_dp_full: u64,
    /// Windows accounted without any stage running (k = 0 requests keep
    /// the partition invariant through this counter).
    pub search_skipped: u64,
    /// Survivor batches flushed through the DP kernel across all
    /// searches (one per window on the scalar path; one per ≤L windows
    /// on the lane-batched path).
    pub search_survivor_batches: u64,
    /// Mean windows per survivor batch (`(dp_abandoned + dp_full) /
    /// survivor_batches`); 1.0 on the scalar path, approaches the lane
    /// count as lane batches fill, 0.0 before any batch has run.
    pub search_lane_occupancy_mean: f64,
    /// Envelope blocks evaluated through the LB prefilter kernel across
    /// all searches (Kim precompute blocks + Keogh verdict blocks; one
    /// per candidate on the scalar prefilter path).
    pub search_lb_blocks: u64,
    /// Candidates evaluated across those LB blocks — the occupancy
    /// numerator.
    pub search_lb_evals: u64,
    /// Keogh evaluations whose sum was early-abandoned before the final
    /// query term (partial bound; a subset of `search_pruned_keogh`).
    pub search_lb_abandons: u64,
    /// Windows cut because a banded search's band admitted no warping
    /// path (`window + band < query`); zero when no banded search ran.
    pub search_pruned_band: u64,
    /// DP cells the Sakoe-Chiba band mask excluded across stage-3
    /// flushes, relative to the unconstrained sweep — the DP work the
    /// band saved; zero when no banded search ran.
    pub search_band_cells_skipped: u64,
    /// Mean candidates per LB block (`search_lb_evals /
    /// search_lb_blocks`); 1.0 on the scalar prefilter path, approaches
    /// the block size as blocks fill, 0.0 before any block has run.
    pub search_lb_block_occupancy_mean: f64,
    pub search_latency_mean_ms: f64,
    pub search_latency_p50_ms: f64,
    pub search_latency_p99_ms: f64,
    /// Searches served by the sharded parallel executor (a subset of
    /// `searches`).
    pub searches_sharded: u64,
    /// Total shards executed across all sharded searches.
    pub search_shards: u64,
    /// Shared-threshold tightenings across all sharded searches.
    pub search_tau_tightenings: u64,
    /// Sharded searches whose shard timings carried signal — the
    /// denominator of `search_imbalance_mean`.  Searches whose timings
    /// all rounded to zero are excluded, not counted as balanced.
    pub search_imbalance_samples: u64,
    /// Mean per-search shard imbalance (slowest / mean shard wall time,
    /// ≥ 1.0, 1.0 = perfectly even) over the searches with measurable
    /// timings; 0.0 until one such search runs.
    pub search_imbalance_mean: f64,
    /// Worker nodes attached to the cluster shard backend (gauge; 0 on
    /// a single-node service).
    pub cluster_nodes: u64,
    /// τ-tightening messages the coordinator pushed to remote nodes
    /// mid-search (one per receiving node per strict improvement).
    pub tau_broadcasts: u64,
    /// Shard chunks a node stole off another node's deque when it
    /// drained its own range first.
    pub shards_stolen: u64,
    /// Connections currently open at the serving front end (gauge; both
    /// the blocking and reactor edges maintain it).
    pub conns_open: u64,
    /// Frames dropped for exceeding the serving edge's max-frame cap.
    pub frames_oversized: u64,
    /// Requests that arrived on a connection that already had at least
    /// one request in flight — how much clients actually pipeline.
    pub requests_pipelined: u64,
    /// Streaming appends served.
    pub stream_appends: u64,
    /// Samples ingested into the streaming session across all appends.
    pub stream_samples: u64,
    /// Streaming (delta-path) searches served.
    pub delta_searches: u64,
    /// Candidates the delta searches actually cascaded.
    pub delta_candidates_scanned: u64,
    /// Candidates the delta searches skipped via the watermark — what a
    /// full rebuild would have re-cascaded.
    pub delta_candidates_skipped: u64,
    /// Per-stage trace aggregates (span counts, total time, Gsps, and
    /// p50/p90/p99 stage latency) from the `obs` span recorder.  Empty
    /// when tracing is disabled (`SDTW_TRACE` unset) or no sampled
    /// request has run yet; purely observational either way.
    pub stages: Vec<obs::StageSummary>,
}

impl MetricsSnapshot {
    /// Fraction of kernel rows wasted on padding.
    pub fn padding_fraction(&self) -> f64 {
        let total = self.real_rows + self.padded_rows;
        if total == 0 {
            0.0
        } else {
            self.padded_rows as f64 / total as f64
        }
    }

    /// Windows pruned before a full DP, across all searches.
    pub fn search_pruned_total(&self) -> u64 {
        self.search_pruned_kim
            + self.search_pruned_keogh
            + self.search_pruned_band
            + self.search_dp_abandoned
            + self.search_skipped
    }

    /// Fraction of candidate windows the cascade pruned, in [0, 1].
    pub fn search_prune_fraction(&self) -> f64 {
        if self.search_windows == 0 {
            0.0
        } else {
            self.search_pruned_total() as f64 / self.search_windows as f64
        }
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "requests={} responses={} errors={} rejected={} batches={} \
             padding={:.1}% device_gsps={:.6} offered_gsps={:.6} \
             latency(mean/p50/p95/p99)={:.2}/{:.2}/{:.2}/{:.2} ms queue={:.2} ms",
            self.requests,
            self.responses,
            self.errors,
            self.rejected,
            self.batches,
            self.padding_fraction() * 100.0,
            self.device_gsps,
            self.offered_gsps,
            self.latency_mean_ms,
            self.latency_p50_ms,
            self.latency_p95_ms,
            self.latency_p99_ms,
            self.queue_mean_ms,
        );
        if self.searches > 0 {
            out.push_str(&format!(
                " searches={} windows={} pruned={:.1}% \
                 (kim={} keogh={} abandoned={} full_dp={}) \
                 survivor_batches={} lane_occupancy={:.2} \
                 lb_blocks={} lb_occupancy={:.2} lb_abandons={} \
                 search_latency(mean/p50/p99)={:.2}/{:.2}/{:.2} ms",
                self.searches,
                self.search_windows,
                self.search_prune_fraction() * 100.0,
                self.search_pruned_kim,
                self.search_pruned_keogh,
                self.search_dp_abandoned,
                self.search_dp_full,
                self.search_survivor_batches,
                self.search_lane_occupancy_mean,
                self.search_lb_blocks,
                self.search_lb_block_occupancy_mean,
                self.search_lb_abandons,
                self.search_latency_mean_ms,
                self.search_latency_p50_ms,
                self.search_latency_p99_ms,
            ));
            if self.search_pruned_band > 0 || self.search_band_cells_skipped > 0 {
                out.push_str(&format!(
                    " band(pruned={} cells_skipped={})",
                    self.search_pruned_band, self.search_band_cells_skipped,
                ));
            }
        }
        if self.searches_sharded > 0 {
            out.push_str(&format!(
                " sharded={} shards={} tightenings={}",
                self.searches_sharded, self.search_shards, self.search_tau_tightenings,
            ));
            if self.search_imbalance_samples > 0 {
                out.push_str(&format!(" imbalance={:.2}", self.search_imbalance_mean));
            } else {
                out.push_str(" imbalance=n/a");
            }
        }
        if self.cluster_nodes > 0 {
            out.push_str(&format!(
                " cluster(nodes={} tau_broadcasts={} shards_stolen={})",
                self.cluster_nodes, self.tau_broadcasts, self.shards_stolen,
            ));
        }
        if self.conns_open > 0 || self.frames_oversized > 0 || self.requests_pipelined > 0 {
            out.push_str(&format!(
                " edge(conns_open={} oversized={} pipelined={})",
                self.conns_open, self.frames_oversized, self.requests_pipelined,
            ));
        }
        if self.stream_appends > 0 || self.delta_searches > 0 {
            out.push_str(&format!(
                " stream(appends={} samples={}) delta_searches={} \
                 delta(scanned={} skipped={})",
                self.stream_appends,
                self.stream_samples,
                self.delta_searches,
                self.delta_candidates_scanned,
                self.delta_candidates_skipped,
            ));
        }
        if !self.stages.is_empty() {
            for st in &self.stages {
                out.push_str(&format!(
                    " stage[{}](spans={} total={:.2}ms gsps={:.6} \
                     p50/p90/p99={:.2}/{:.2}/{:.2}ms)",
                    st.stage,
                    st.spans,
                    st.total_ms,
                    st.gsps,
                    st.p50_ms,
                    st.p90_ms,
                    st.p99_ms,
                ));
            }
        }
        out
    }

    /// Look up one stage's trace aggregate by name (`"envelope"`,
    /// `"keogh"`, `"dp"`, `"shard"`, `"delta"`, `"search"`); `None`
    /// when tracing is off or the stage has not run.
    pub fn stage(&self, name: &str) -> Option<&obs::StageSummary> {
        self.stages.iter().find(|s| s.stage == name)
    }

    /// Render the snapshot in Prometheus text exposition format
    /// (version 0.0.4): `# HELP`/`# TYPE` headers followed by one
    /// `sdtw_*` sample per line.  Percentiles are exported as gauges
    /// with a `quantile` label (pre-aggregated, not a native summary)
    /// so scrapers need no histogram support.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let mut counter = |name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        };
        counter("sdtw_requests_total", "Align submissions accepted.", self.requests);
        counter("sdtw_responses_total", "Align responses delivered.", self.responses);
        counter("sdtw_errors_total", "Requests that failed.", self.errors);
        counter("sdtw_rejected_total", "Align submissions rejected at admission.", self.rejected);
        counter("sdtw_batches_total", "Device batches executed.", self.batches);
        counter("sdtw_searches_total", "Top-K searches served.", self.searches);
        counter(
            "sdtw_search_windows_total",
            "Candidate windows considered across all searches.",
            self.search_windows,
        );
        counter(
            "sdtw_search_pruned_kim_total",
            "Windows pruned by the LB_Kim stage.",
            self.search_pruned_kim,
        );
        counter(
            "sdtw_search_pruned_keogh_total",
            "Windows pruned by the LB_Keogh stage.",
            self.search_pruned_keogh,
        );
        counter(
            "sdtw_search_dp_abandoned_total",
            "Windows whose DP was abandoned mid-recurrence.",
            self.search_dp_abandoned,
        );
        counter(
            "sdtw_search_dp_full_total",
            "Windows that ran a full exact DP.",
            self.search_dp_full,
        );
        counter(
            "sdtw_search_pruned_band_total",
            "Windows cut because the Sakoe-Chiba band admitted no warping path.",
            self.search_pruned_band,
        );
        counter(
            "sdtw_search_band_cells_skipped_total",
            "DP cells the Sakoe-Chiba band mask excluded in stage 3.",
            self.search_band_cells_skipped,
        );
        counter(
            "sdtw_tau_broadcasts_total",
            "Tau tightenings broadcast to remote cluster nodes mid-search.",
            self.tau_broadcasts,
        );
        counter(
            "sdtw_shards_stolen_total",
            "Shard chunks stolen across cluster nodes for load balance.",
            self.shards_stolen,
        );
        counter(
            "sdtw_frames_oversized_total",
            "Frames dropped for exceeding the max-frame cap.",
            self.frames_oversized,
        );
        counter(
            "sdtw_requests_pipelined_total",
            "Requests that arrived with one already in flight on the same connection.",
            self.requests_pipelined,
        );
        counter(
            "sdtw_stream_appends_total",
            "Streaming appends served.",
            self.stream_appends,
        );
        counter(
            "sdtw_delta_searches_total",
            "Streaming delta searches served.",
            self.delta_searches,
        );
        let mut gauge = |name: &str, help: &str, v: f64| {
            let v = if v.is_finite() { v } else { 0.0 };
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        };
        gauge(
            "sdtw_device_gsps",
            "Paper eq. 3 throughput over device busy time.",
            self.device_gsps,
        );
        gauge(
            "sdtw_offered_gsps",
            "Paper eq. 3 throughput over wall time.",
            self.offered_gsps,
        );
        gauge(
            "sdtw_conns_open",
            "Connections currently open at the serving front end.",
            self.conns_open as f64,
        );
        gauge(
            "sdtw_cluster_nodes",
            "Worker nodes attached to the cluster shard backend.",
            self.cluster_nodes as f64,
        );
        gauge(
            "sdtw_search_prune_fraction",
            "Fraction of candidate windows pruned before a full DP.",
            self.search_prune_fraction(),
        );
        // latency quantiles: pre-aggregated gauges with a quantile label
        let mut quantiles = |name: &str, help: &str, samples: &[(&str, f64)]| {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            for (q, v) in samples {
                let v = if v.is_finite() { *v } else { 0.0 };
                out.push_str(&format!("{name}{{quantile=\"{q}\"}} {v}\n"));
            }
        };
        quantiles(
            "sdtw_latency_ms",
            "End-to-end align latency quantiles in milliseconds.",
            &[
                ("0.5", self.latency_p50_ms),
                ("0.95", self.latency_p95_ms),
                ("0.99", self.latency_p99_ms),
            ],
        );
        quantiles(
            "sdtw_search_latency_ms",
            "Top-K search latency quantiles in milliseconds.",
            &[
                ("0.5", self.search_latency_p50_ms),
                ("0.99", self.search_latency_p99_ms),
            ],
        );
        if !self.stages.is_empty() {
            out.push_str(
                "# HELP sdtw_stage_spans_total Trace spans recorded per cascade stage.\n\
                 # TYPE sdtw_stage_spans_total counter\n",
            );
            for st in &self.stages {
                out.push_str(&format!(
                    "sdtw_stage_spans_total{{stage=\"{}\"}} {}\n",
                    st.stage, st.spans
                ));
            }
            out.push_str(
                "# HELP sdtw_stage_total_ms Total traced time per cascade stage in milliseconds.\n\
                 # TYPE sdtw_stage_total_ms counter\n",
            );
            for st in &self.stages {
                let v = if st.total_ms.is_finite() { st.total_ms } else { 0.0 };
                out.push_str(&format!(
                    "sdtw_stage_total_ms{{stage=\"{}\"}} {v}\n",
                    st.stage
                ));
            }
            out.push_str(
                "# HELP sdtw_stage_gsps Paper eq. 3 throughput per cascade stage.\n\
                 # TYPE sdtw_stage_gsps gauge\n",
            );
            for st in &self.stages {
                let v = if st.gsps.is_finite() { st.gsps } else { 0.0 };
                out.push_str(&format!("sdtw_stage_gsps{{stage=\"{}\"}} {v}\n", st.stage));
            }
            out.push_str(
                "# HELP sdtw_stage_latency_ms Per-stage span duration quantiles in milliseconds.\n\
                 # TYPE sdtw_stage_latency_ms gauge\n",
            );
            for st in &self.stages {
                for (q, v) in [("0.5", st.p50_ms), ("0.9", st.p90_ms), ("0.99", st.p99_ms)] {
                    let v = if v.is_finite() { v } else { 0.0 };
                    out.push_str(&format!(
                        "sdtw_stage_latency_ms{{stage=\"{}\",quantile=\"{q}\"}} {v}\n",
                        st.stage
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_batch(2, 6, 128, 2048);
        m.on_execute(10.0);
        m.on_response(12.0);
        m.on_response(14.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.responses, 2);
        assert_eq!(s.batches, 1);
        assert_eq!(s.real_rows, 2);
        assert_eq!(s.padded_rows, 6);
        assert_eq!(s.floats_processed, 2 * 128);
        assert_eq!(s.cells, 2 * 128 * 2048);
        assert!((s.padding_fraction() - 0.75).abs() < 1e-12);
        assert!((s.latency_mean_ms - 13.0).abs() < 1e-9);
        assert!(s.busy_ms >= 9.9 && s.busy_ms <= 10.1);
        // device gsps: 256 floats / 10ms = 256 / 1e7 s·1e9 = 2.56e-5
        assert!((s.device_gsps - 2.56e-5).abs() < 1e-7, "{}", s.device_gsps);
    }

    #[test]
    fn empty_snapshot_is_finite() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.device_gsps, 0.0);
        assert_eq!(s.padding_fraction(), 0.0);
        assert_eq!(s.searches, 0);
        assert_eq!(s.search_prune_fraction(), 0.0);
        // render must not panic
        let _ = s.render();
    }

    #[test]
    fn search_counters_accumulate() {
        let m = Metrics::new();
        m.on_search(
            2.0,
            &CascadeStats {
                candidates: 100,
                pruned_kim: 60,
                pruned_keogh: 20,
                dp_abandoned: 10,
                dp_full: 10,
                skipped: 0,
                survivor_batches: 5,
                lb_blocks: 10,
                lb_evals: 40,
                lb_abandons: 12,
                pruned_band: 0,
                band_cells_skipped: 0,
            },
        );
        m.on_search(
            4.0,
            &CascadeStats {
                candidates: 100,
                pruned_kim: 80,
                pruned_keogh: 0,
                dp_abandoned: 0,
                dp_full: 20,
                skipped: 0,
                survivor_batches: 5,
                lb_blocks: 10,
                lb_evals: 20,
                lb_abandons: 0,
                pruned_band: 0,
                band_cells_skipped: 0,
            },
        );
        let s = m.snapshot();
        assert_eq!(s.searches, 2);
        assert_eq!(s.search_windows, 200);
        assert_eq!(s.search_pruned_kim, 140);
        assert_eq!(s.search_pruned_keogh, 20);
        assert_eq!(s.search_dp_abandoned, 10);
        assert_eq!(s.search_dp_full, 30);
        assert_eq!(s.search_pruned_total(), 170);
        assert_eq!(s.search_survivor_batches, 10);
        // 40 survivor lanes over 10 batches
        assert!((s.search_lane_occupancy_mean - 4.0).abs() < 1e-12);
        assert_eq!(s.search_lb_blocks, 20);
        assert_eq!(s.search_lb_evals, 60);
        assert_eq!(s.search_lb_abandons, 12);
        // 60 LB evaluations over 20 blocks
        assert!((s.search_lb_block_occupancy_mean - 3.0).abs() < 1e-12);
        assert!((s.search_prune_fraction() - 0.85).abs() < 1e-12);
        assert!((s.search_latency_mean_ms - 3.0).abs() < 1e-9);
        assert!(s.render().contains("searches=2"));
        assert!(s.render().contains("survivor_batches=10"));
        assert!(s.render().contains("lb_blocks=20"));
        assert!(s.render().contains("lb_abandons=12"));
        // no sharded searches yet: the sharded block stays hidden
        assert_eq!(s.searches_sharded, 0);
        assert!(!s.render().contains("sharded="));
    }

    #[test]
    fn band_counters_accumulate_and_render_only_when_banded() {
        let m = Metrics::new();
        // an unbanded search leaves the band counters at zero and the
        // band block hidden
        m.on_search(1.0, &CascadeStats { candidates: 10, dp_full: 10, ..Default::default() });
        let s = m.snapshot();
        assert_eq!(s.search_pruned_band, 0);
        assert_eq!(s.search_band_cells_skipped, 0);
        assert!(!s.render().contains("band("));
        // a banded search feeds both counters and the partition total
        m.on_search(
            2.0,
            &CascadeStats {
                candidates: 50,
                pruned_kim: 10,
                dp_full: 20,
                pruned_band: 20,
                band_cells_skipped: 1234,
                ..Default::default()
            },
        );
        let s = m.snapshot();
        assert_eq!(s.search_pruned_band, 20);
        assert_eq!(s.search_band_cells_skipped, 1234);
        assert_eq!(
            s.search_pruned_total() + s.search_dp_full,
            s.search_windows,
            "band prunes must stay inside the partition invariant"
        );
        assert!(s.render().contains("band(pruned=20 cells_skipped=1234)"));
        let text = s.render_prometheus();
        assert!(text.contains("sdtw_search_pruned_band_total 20"));
        assert!(text.contains("sdtw_search_band_cells_skipped_total 1234"));
    }

    #[test]
    fn lane_occupancy_zero_before_any_batch() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.search_survivor_batches, 0);
        assert_eq!(s.search_lane_occupancy_mean, 0.0);
        assert_eq!(s.search_lb_blocks, 0);
        assert_eq!(s.search_lb_block_occupancy_mean, 0.0);
    }

    #[test]
    fn sharded_search_counters_accumulate() {
        let m = Metrics::new();
        let stats = CascadeStats {
            candidates: 100,
            pruned_kim: 60,
            pruned_keogh: 20,
            dp_abandoned: 10,
            dp_full: 10,
            skipped: 0,
            survivor_batches: 4,
            lb_blocks: 8,
            lb_evals: 30,
            lb_abandons: 5,
            pruned_band: 0,
            band_cells_skipped: 0,
        };
        m.on_search_sharded(2.0, &stats, 4, 12, Some(1.5));
        m.on_search_sharded(4.0, &stats, 8, 4, Some(2.5));
        let s = m.snapshot();
        // a sharded search is still a search
        assert_eq!(s.searches, 2);
        assert_eq!(s.search_windows, 200);
        assert_eq!(s.searches_sharded, 2);
        assert_eq!(s.search_shards, 12);
        assert_eq!(s.search_tau_tightenings, 16);
        assert_eq!(s.search_imbalance_samples, 2);
        assert!((s.search_imbalance_mean - 2.0).abs() < 1e-9);
        let r = s.render();
        assert!(r.contains("sharded=2"));
        assert!(r.contains("shards=12"));
        assert!(r.contains("tightenings=16"));
    }

    #[test]
    fn cluster_counters_accumulate_and_render_only_when_attached() {
        let m = Metrics::new();
        let stats = CascadeStats { candidates: 10, dp_full: 10, ..Default::default() };
        // before a backend attaches, the cluster block stays hidden even
        // if a (hypothetical) cluster search ran
        let s = m.snapshot();
        assert_eq!(s.cluster_nodes, 0);
        assert!(!s.render().contains("cluster("));
        m.set_cluster_nodes(3);
        m.on_search_cluster(2.0, &stats, 8, 5, 10, 2);
        m.on_search_cluster(4.0, &stats, 8, 1, 2, 0);
        let s = m.snapshot();
        // a cluster search is a sharded search is a search
        assert_eq!(s.searches, 2);
        assert_eq!(s.searches_sharded, 2);
        assert_eq!(s.search_shards, 16);
        assert_eq!(s.search_tau_tightenings, 6);
        // no per-shard wall times at the coordinator: never an imbalance sample
        assert_eq!(s.search_imbalance_samples, 0);
        assert_eq!(s.cluster_nodes, 3);
        assert_eq!(s.tau_broadcasts, 12);
        assert_eq!(s.shards_stolen, 2);
        let r = s.render();
        assert!(r.contains("cluster(nodes=3 tau_broadcasts=12 shards_stolen=2)"));
        let text = s.render_prometheus();
        assert!(text.contains("sdtw_cluster_nodes 3"));
        assert!(text.contains("sdtw_tau_broadcasts_total 12"));
        assert!(text.contains("sdtw_shards_stolen_total 2"));
    }

    #[test]
    fn unmeasurable_imbalance_excluded_from_mean() {
        let m = Metrics::new();
        let stats = CascadeStats { candidates: 10, dp_full: 10, ..Default::default() };
        // a fast search with zero-rounded shard timings: no imbalance signal
        m.on_search_sharded(0.0, &stats, 2, 0, None);
        let s = m.snapshot();
        assert_eq!(s.searches_sharded, 1);
        assert_eq!(s.search_imbalance_samples, 0);
        assert_eq!(s.search_imbalance_mean, 0.0);
        assert!(s.render().contains("imbalance=n/a"));
        // a measured search restores the mean over measured samples only
        m.on_search_sharded(3.0, &stats, 2, 1, Some(1.5));
        let s = m.snapshot();
        assert_eq!(s.search_imbalance_samples, 1);
        assert!((s.search_imbalance_mean - 1.5).abs() < 1e-9);
        assert!(s.render().contains("imbalance=1.50"));
    }

    #[test]
    fn skipped_windows_keep_partition_invariant() {
        let m = Metrics::new();
        // a k=0 search: every candidate accounted as skipped
        m.on_search(0.5, &CascadeStats { candidates: 40, skipped: 40, ..Default::default() });
        let s = m.snapshot();
        assert_eq!(s.search_windows, 40);
        assert_eq!(s.search_skipped, 40);
        assert_eq!(s.search_pruned_total(), 40);
        assert_eq!(
            s.search_pruned_total() + s.search_dp_full,
            s.search_windows,
            "stages must partition the candidate space even at k=0"
        );
    }

    #[test]
    fn prometheus_rendering_is_line_formatted() {
        let m = Metrics::new();
        m.on_submit();
        m.on_search(2.0, &CascadeStats { candidates: 10, dp_full: 10, ..Default::default() });
        let text = m.snapshot().render_prometheus();
        assert!(text.contains("# TYPE sdtw_requests_total counter"));
        assert!(text.contains("sdtw_requests_total 1"));
        assert!(text.contains("sdtw_searches_total 1"));
        assert!(text.contains("sdtw_latency_ms{quantile=\"0.5\"}"));
        assert!(text.contains("# TYPE sdtw_offered_gsps gauge"));
        // every non-comment line is `name{labels} value` with a finite value
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
            assert!(!name.is_empty(), "empty metric name in {line:?}");
            let v: f64 = value
                .parse()
                .unwrap_or_else(|_| panic!("unparseable sample value in {line:?}"));
            assert!(v.is_finite(), "non-finite sample in {line:?}");
        }
    }

    #[test]
    fn streaming_counters_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!(s.stream_appends, 0);
        assert!(!s.render().contains("stream("), "hidden until streaming is used");
        m.on_stream_append(1000);
        m.on_stream_append(24);
        m.on_delta_search(300, 0);
        m.on_delta_search(40, 300);
        let s = m.snapshot();
        assert_eq!(s.stream_appends, 2);
        assert_eq!(s.stream_samples, 1024);
        assert_eq!(s.delta_searches, 2);
        assert_eq!(s.delta_candidates_scanned, 340);
        assert_eq!(s.delta_candidates_skipped, 300);
        let r = s.render();
        assert!(r.contains("stream(appends=2 samples=1024)"));
        assert!(r.contains("delta_searches=2"));
        assert!(r.contains("delta(scanned=340 skipped=300)"));
    }
}
