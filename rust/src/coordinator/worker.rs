//! Batch executor workers: marshal an assembled batch into host tensors,
//! run the routed variant on a PJRT engine, and fan results back out to
//! the per-request reply channels.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::Batch;
use super::metrics::Metrics;
use super::queue::BoundedQueue;
use super::request::AlignResponse;
use crate::log_warn;
use crate::normalize;
use crate::runtime::artifact::{Kind, VariantMeta};
use crate::runtime::{EngineHandle, HostTensor};

/// A batch routed to a concrete variant.
pub struct RoutedBatch {
    pub variant: Arc<VariantMeta>,
    pub batch: Batch,
}

/// Worker loop: pop routed batches until the queue closes.
pub fn worker_loop(
    queue: Arc<BoundedQueue<RoutedBatch>>,
    engine: EngineHandle,
    reference_norm: Arc<Vec<f32>>,
    metrics: Arc<Metrics>,
) {
    while let Some(rb) = queue.pop() {
        let variant = rb.variant.clone();
        match execute_batch(&engine, &variant, &reference_norm, &rb, &metrics) {
            Ok(responses) => {
                for (req, resp) in rb.batch.requests.iter().zip(responses) {
                    metrics.on_response(resp.latency_ms);
                    if req.reply.try_send(Ok(resp)).is_err() {
                        // caller went away; not a service error
                    }
                }
            }
            Err(e) => {
                metrics.on_error();
                log_warn!("batch on {} failed: {e:#}", variant.name);
                let msg = format!("execution failed: {e:#}");
                for req in &rb.batch.requests {
                    let _ = req.reply.try_send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Build inputs, execute, split outputs into per-request responses.
fn execute_batch(
    engine: &EngineHandle,
    variant: &VariantMeta,
    reference_norm: &[f32],
    rb: &RoutedBatch,
    metrics: &Arc<Metrics>,
) -> Result<Vec<AlignResponse>> {
    let b = variant.batch;
    let m = variant.qlen;
    let n = variant.reflen.context("alignment variant lacks reflen")?;
    let batch = &rb.batch;
    assert!(batch.requests.len() <= b, "batch overflow");

    metrics.on_batch(batch.requests.len(), b - batch.requests.len(), m, n);
    metrics.on_queue_time(batch.assembled.elapsed().as_secs_f64() * 1e3);

    // assemble the (B, M) query tensor, zero-padding unused rows
    let mut queries = vec![0f32; b * m];
    for (row, req) in batch.requests.iter().enumerate() {
        anyhow::ensure!(
            req.query.len() == m,
            "request {} qlen {} != variant qlen {m}",
            req.id,
            req.query.len()
        );
        queries[row * m..(row + 1) * m].copy_from_slice(&req.query);
    }
    // `sdtw`-kind variants take pre-normalized queries (the pipeline
    // kinds normalize on device); match the paper's flow host-side.
    if variant.kind == Kind::Sdtw {
        normalize::znorm_batch(&mut queries[..batch.requests.len() * m], m);
    }

    let inputs = vec![
        HostTensor::f32(&[b as i64, m as i64], queries)?,
        HostTensor::f32(&[n as i64], reference_norm.to_vec())?,
    ];
    let result = engine.execute(&variant.name, inputs)?;
    metrics.on_execute(result.exec_ms);

    anyhow::ensure!(
        result.outputs.len() == 2,
        "expected (costs, positions), got {} outputs",
        result.outputs.len()
    );
    let costs = result.outputs[0].as_f32()?;
    let positions = result.outputs[1].as_i32()?;
    anyhow::ensure!(costs.len() == b && positions.len() == b, "bad output shape");

    let now = Instant::now();
    Ok(batch
        .requests
        .iter()
        .enumerate()
        .map(|(row, req)| AlignResponse {
            id: req.id,
            cost: costs[row],
            end: positions[row].max(0) as usize,
            latency_ms: now.duration_since(req.submitted).as_secs_f64() * 1e3,
            variant: variant.name.clone(),
        })
        .collect())
}

#[cfg(test)]
mod tests {
    // worker_loop is exercised end-to-end by tests/integration_coordinator.rs
    // (it needs real artifacts); the marshalling invariants are covered there.
}
