//! Layer-3 coordinator — the serving system around the AOT kernels.
//!
//! The paper's kernel is batch-oriented ("batches of 512 queries of
//! length 2,000"); what it leaves to the caller — collecting queries into
//! full batches, normalizing the reference, routing to the right compiled
//! shape, and getting results back to whoever asked — is this module, in
//! the mold of a vLLM-style request router:
//!
//! ```text
//!  submit() ──► BoundedQueue ──► dispatcher (deadline batcher)
//!                                   │ round-robin
//!                                   ▼
//!                        BoundedQueue<Batch> ──► worker × W
//!                                                  │ EngineHandle
//!                                                  ▼
//!                                        PJRT execute (artifact)
//!                                                  │
//!                reply channel per request ◄───────┘  + metrics
//! ```
//!
//! * [`queue`]    — Mutex+Condvar bounded MPMC queue with close semantics
//!   (backpressure for the paper's fixed-batch kernels).
//! * [`batcher`]  — size/deadline batch assembly + padding policy.
//! * [`router`]   — request → variant selection against the manifest.
//! * [`worker`]   — tensor marshalling + execution + response fan-out.
//! * [`metrics`]  — Gsps (paper eq. 3), latency percentiles, padding waste.
//! * [`service`]  — [`service::SdtwService`], the public facade.
//!
//! The `search` verb takes a different path through the same facade:
//! it bypasses the kernel batcher (the LB cascade prunes most of its
//! work away, leaving little to batch) and runs on the calling thread —
//! or, when [`SearchOptions::shards`] resolves above 1, fans out across
//! the sharded executor's worker pool (`crate::search::sharded`), which
//! reuses this module's [`queue::BoundedQueue`] as its work queue.
//!
//! The `append` verb grows a streaming session
//! (`crate::search::streaming`): raw samples are mapped into the
//! frozen startup normalization frame and indexed incrementally; a
//! `search` with `stream: true` then runs against the grown stream,
//! cascading only the delta since the last identical search.  See
//! `docs/ARCHITECTURE.md` for the full life-of-a-request walkthroughs.

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod router;
pub mod service;
pub mod worker;

pub use batcher::{Batch, BatchPolicy};
pub use metrics::{Metrics, MetricsSnapshot};
pub use queue::BoundedQueue;
pub use request::{
    AlignOptions, AlignRequest, AlignResponse, AppendOptions, AppendResponse, RequestId,
    ResolvedSearch, SearchOptions, SearchResponse,
};
pub use router::Router;
pub use service::{SdtwService, ServiceOptions};
