//! Request/response types of the alignment service.

use std::sync::mpsc;
use std::time::Instant;

pub type RequestId = u64;

/// Client-facing alignment options (used by the router).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AlignOptions {
    /// Route to the pruned kernel variant if available.
    pub pruned: bool,
    /// Route to the quantized pipeline if available.
    pub quantized: bool,
    /// Prefer a reduced-precision accumulator variant ("bf16"/"f16").
    pub half: bool,
}

/// One alignment request: a raw (un-normalized) query against the
/// service's reference.
#[derive(Debug)]
pub struct AlignRequest {
    pub id: RequestId,
    pub query: Vec<f32>,
    pub options: AlignOptions,
    /// Set at submission; used for end-to-end latency metrics.
    pub submitted: Instant,
    /// Where the response goes (one-shot).
    pub reply: mpsc::SyncSender<Result<AlignResponse, String>>,
}

/// The alignment answer.
#[derive(Clone, Debug, PartialEq)]
pub struct AlignResponse {
    pub id: RequestId,
    /// Accumulated sDTW cost (+inf encodes "no match" under pruning).
    pub cost: f32,
    /// Match end position in the reference.
    pub end: usize,
    /// End-to-end latency in milliseconds (submit → response build).
    pub latency_ms: f64,
    /// Name of the variant that served the request.
    pub variant: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn options_default_is_exact_f32() {
        let o = AlignOptions::default();
        assert!(!o.pruned && !o.quantized && !o.half);
    }

    #[test]
    fn request_reply_roundtrip() {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = AlignRequest {
            id: 7,
            query: vec![1.0, 2.0],
            options: AlignOptions::default(),
            submitted: Instant::now(),
            reply: tx,
        };
        req.reply
            .send(Ok(AlignResponse {
                id: req.id,
                cost: 0.5,
                end: 3,
                latency_ms: 1.0,
                variant: "v".into(),
            }))
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.end, 3);
    }
}
