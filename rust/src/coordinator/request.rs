//! Request/response types of the alignment service.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::dtw::kernel::{KernelKind, KernelSpec};
use crate::search::{
    effective_band, CascadeOpts, CascadeStats, Hit, LbKernelKind, LbKernelSpec,
};

pub type RequestId = u64;

/// Client-facing alignment options (used by the router).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AlignOptions {
    /// Route to the pruned kernel variant if available.
    pub pruned: bool,
    /// Route to the quantized pipeline if available.
    pub quantized: bool,
    /// Prefer a reduced-precision accumulator variant ("bf16"/"f16").
    pub half: bool,
}

/// One alignment request: a raw (un-normalized) query against the
/// service's reference.
#[derive(Debug)]
pub struct AlignRequest {
    pub id: RequestId,
    pub query: Vec<f32>,
    pub options: AlignOptions,
    /// Set at submission; used for end-to-end latency metrics.
    pub submitted: Instant,
    /// Where the response goes (one-shot).
    pub reply: mpsc::SyncSender<Result<AlignResponse, String>>,
}

/// The alignment answer.
#[derive(Clone, Debug, PartialEq)]
pub struct AlignResponse {
    pub id: RequestId,
    /// Accumulated sDTW cost (+inf encodes "no match" under pruning).
    pub cost: f32,
    /// Match end position in the reference.
    pub end: usize,
    /// End-to-end latency in milliseconds (submit → response build).
    pub latency_ms: f64,
    /// Name of the variant that served the request.
    pub variant: String,
}

/// Client-facing top-K search options.  Zero means "auto": `window`
/// defaults to 3·qlen/2 (clamped to the reference), `exclusion` to half
/// the window, `shards` to one per worker thread and `parallelism` to
/// the host's available parallelism — all resolved by the service per
/// request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SearchOptions {
    /// Number of match sites to return.
    pub k: usize,
    /// Candidate window length (0 = auto).
    pub window: usize,
    /// Candidate stride over the reference.
    pub stride: usize,
    /// Trivial-match exclusion: minimum start distance between two
    /// reported sites (0 = auto).
    pub exclusion: usize,
    /// Index shards cascaded with a shared prune threshold (1 = the
    /// serial engine, the default; 0 = auto: one shard per worker).
    pub shards: usize,
    /// Worker threads for the sharded executor (1 = default; 0 = auto:
    /// the host's available parallelism).  Ignored when `shards`
    /// resolves to 1.
    pub parallelism: usize,
    /// DP kernel for stage-3 survivors: scalar (default), blocked scan,
    /// or the lane-batched lockstep executor.  Every choice returns
    /// bit-identical hits (the kernel layer's invariant).
    pub kernel: KernelKind,
    /// Lane count for the lane kernel (0 = auto).  Ignored unless
    /// `kernel` is [`KernelKind::Lanes`].
    pub lanes: usize,
    /// Lower-bound prefilter kernel for the Kim/Keogh stages: scalar
    /// (default, per-candidate) or the SoA block kernel that evaluates
    /// whole envelope blocks in lockstep.  Every choice returns
    /// bit-identical hits (the cascade's τ-refresh argument).
    pub lb_kernel: LbKernelKind,
    /// Candidates per envelope block for the block LB kernel (0 =
    /// auto).  Ignored unless `lb_kernel` is [`LbKernelKind::Block`].
    pub lb_block: usize,
    /// Search the streaming session (grown by `append`) instead of the
    /// startup reference.  Serial streaming searches cascade only the
    /// candidates appended since the last identical search (the delta);
    /// results stay bit-identical to a full rebuild.
    pub stream: bool,
    /// Explain sample mode: record which cascade stage pruned each
    /// sampled candidate (and at what bound vs τ) into the obs explain
    /// buffer.  Purely observational — hits and counters stay
    /// bit-identical with it on or off (see `docs/OBSERVABILITY.md`).
    pub explain: bool,
    /// Sakoe-Chiba band radius for the anchored banded search semantics
    /// (`crate::search::cascade` module docs).  `0` (the default)
    /// disables the band; a radius of at least the resolved window is
    /// equivalent to `0` (resolved at the cascade's options layer, so
    /// the mapping is identical on every path).
    pub band: usize,
}

impl Default for SearchOptions {
    fn default() -> Self {
        Self {
            k: 5,
            window: 0,
            stride: 1,
            exclusion: 0,
            shards: 1,
            parallelism: 1,
            kernel: KernelKind::Scalar,
            lanes: 0,
            lb_kernel: LbKernelKind::Scalar,
            lb_block: 0,
            stream: false,
            explain: false,
            band: 0,
        }
    }
}

/// Every auto (`0`) field of a [`SearchOptions`] resolved against a
/// concrete query/reference shape in one validated pass — the single
/// options surface the service, CLI, and cluster coordinator consume.
/// Replaces the accreted per-field resolvers (`resolve_exclusion`,
/// `resolve_kernel`, `resolve_lb_kernel`, `resolve_sharding`,
/// `effective_band` call sites); with exactly one resolver the verbs
/// cannot drift.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResolvedSearch {
    /// Match sites to return (validated `>= 1`).
    pub k: usize,
    /// Concrete candidate window length.
    pub window: usize,
    /// Concrete candidate stride (`>= 1`).
    pub stride: usize,
    /// Concrete trivial-match exclusion (`>= 1`).
    pub exclusion: usize,
    /// Concrete shard count (`1` = the serial engine).
    pub shards: usize,
    /// Concrete executor thread budget (`>= 1`).
    pub parallelism: usize,
    /// Stage-3 DP kernel selection (auto params stay 0 for
    /// `KernelSpec::instantiate`).
    pub kernel: KernelSpec,
    /// Kim/Keogh prefilter kernel selection.
    pub lb_kernel: LbKernelSpec,
    /// Effective Sakoe-Chiba radius: already mapped through
    /// [`effective_band`], so a radius that covers the window has
    /// collapsed to `0` (= unconstrained) here.
    pub band: usize,
}

impl ResolvedSearch {
    /// The cascade options this resolution selects — the one place the
    /// kernel/LB/band knobs turn into [`CascadeOpts`].
    pub fn cascade_opts(&self) -> CascadeOpts {
        CascadeOpts::default()
            .with_kernel(self.kernel)
            .with_lb(self.lb_kernel)
            .with_band(self.band)
    }
}

impl SearchOptions {
    /// Resolve every auto (zero) field against a concrete
    /// query/reference shape, validating as it goes.  The single
    /// definition of the protocol's "0 = auto" semantics — used by the
    /// service, the CLI, and the cluster coordinator so they cannot
    /// drift.
    pub fn resolve(&self, qlen: usize, reflen: usize) -> Result<ResolvedSearch> {
        anyhow::ensure!(qlen >= 1, "empty query");
        let window = if self.window == 0 {
            (qlen + qlen / 2).min(reflen)
        } else {
            self.window
        };
        anyhow::ensure!(
            window <= reflen,
            "window {window} exceeds reference length {reflen}"
        );
        self.resolve_for_window(window)
    }

    /// Resolve against an already-fixed window — the streaming session
    /// and cluster paths, where the live index's shape wins and the
    /// request has already been checked against it.
    pub fn resolve_for_window(&self, window: usize) -> Result<ResolvedSearch> {
        anyhow::ensure!(self.k >= 1, "k must be >= 1");
        anyhow::ensure!(window >= 1, "window must be >= 1");
        let parallelism = if self.parallelism == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.parallelism
        };
        let shards = if self.shards == 0 { parallelism } else { self.shards };
        let exclusion = if self.exclusion == 0 {
            (window / 2).max(1)
        } else {
            self.exclusion
        };
        Ok(ResolvedSearch {
            k: self.k,
            window,
            stride: self.stride.max(1),
            exclusion,
            shards,
            parallelism,
            kernel: KernelSpec { kind: self.kernel, width: 0, lanes: self.lanes },
            lb_kernel: LbKernelSpec { kind: self.lb_kernel, block: self.lb_block },
            band: effective_band(self.band, window).unwrap_or(0),
        })
    }
}

/// Client-facing append options for the streaming search session.
/// Zero means "auto", resolved exactly like [`SearchOptions::resolve`]
/// against the service's primary query length; the first append fixes
/// the session's shape and later appends must match (or stay auto).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AppendOptions {
    /// Candidate window length (0 = auto: 3·qlen/2 of the primary
    /// variant, clamped to the startup reference).
    pub window: usize,
    /// Candidate stride (0 = 1).
    pub stride: usize,
}

/// The append answer: what the streaming session looks like after the
/// samples were ingested.
#[derive(Clone, Debug, PartialEq)]
pub struct AppendResponse {
    pub id: RequestId,
    /// Samples ingested by this append.
    pub appended: usize,
    /// Total stream length (startup reference + all appends).
    pub stream_len: usize,
    /// Candidate windows currently indexed.
    pub candidates: usize,
    /// The session's candidate window length.
    pub window: usize,
    /// The session's candidate stride.
    pub stride: usize,
    /// End-to-end latency in milliseconds.
    pub latency_ms: f64,
}

/// The search answer: top-K sites plus the cascade's pruning telemetry.
#[derive(Clone, Debug, PartialEq)]
pub struct SearchResponse {
    pub id: RequestId,
    /// Best-first, non-overlapping match sites.
    pub hits: Vec<Hit>,
    /// End-to-end latency in milliseconds.
    pub latency_ms: f64,
    /// Per-stage cascade counters for this search (merged over shards).
    pub stats: CascadeStats,
    /// Shards executed (1 = the serial cascade path).
    pub shards: usize,
    /// Times the shared prune threshold tightened (0 on the serial path,
    /// where τ lives in a single local heap).
    pub tau_tightenings: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_options_default_is_auto() {
        let o = SearchOptions::default();
        assert_eq!(o.k, 5);
        assert_eq!(o.window, 0);
        assert_eq!(o.stride, 1);
        assert_eq!(o.exclusion, 0);
        assert_eq!(o.shards, 1, "default is the serial path");
        assert_eq!(o.parallelism, 1);
        assert_eq!(o.kernel, KernelKind::Scalar, "default is the oracle kernel");
        assert_eq!(o.lanes, 0);
        assert_eq!(o.lb_kernel, LbKernelKind::Scalar, "default is the scalar prefilter");
        assert_eq!(o.lb_block, 0);
        assert!(!o.stream, "default targets the startup reference");
        assert!(!o.explain, "explain sampling is opt-in");
        assert_eq!(o.band, 0, "default is the unconstrained search");
    }

    #[test]
    fn append_options_default_is_auto() {
        let o = AppendOptions::default();
        assert_eq!(o.window, 0);
        assert_eq!(o.stride, 0);
    }

    #[test]
    fn search_options_resolve_kernel() {
        assert_eq!(
            SearchOptions::default().resolve(128, 2048).unwrap().kernel,
            KernelSpec::SCALAR
        );
        let o = SearchOptions { kernel: KernelKind::Lanes, lanes: 16, ..Default::default() };
        let spec = o.resolve(128, 2048).unwrap().kernel;
        assert_eq!(spec.kind, KernelKind::Lanes);
        assert_eq!(spec.lanes, 16);
    }

    #[test]
    fn search_options_resolve_lb_kernel() {
        assert_eq!(
            SearchOptions::default().resolve(128, 2048).unwrap().lb_kernel,
            LbKernelSpec::SCALAR
        );
        let o = SearchOptions {
            lb_kernel: LbKernelKind::Block,
            lb_block: 32,
            ..Default::default()
        };
        let spec = o.resolve(128, 2048).unwrap().lb_kernel;
        assert_eq!(spec.kind, LbKernelKind::Block);
        assert_eq!(spec.block, 32);
    }

    #[test]
    fn search_options_resolve_auto_and_explicit() {
        let auto = SearchOptions::default().resolve(128, 2048).unwrap();
        assert_eq!((auto.window, auto.stride, auto.exclusion), (192, 1, 96));
        // auto window clamps to the reference
        let clamped = SearchOptions::default().resolve(128, 150).unwrap();
        assert_eq!((clamped.window, clamped.stride, clamped.exclusion), (150, 1, 75));
        let explicit =
            SearchOptions { k: 3, window: 64, stride: 0, exclusion: 7, ..Default::default() };
        let r = explicit.resolve(128, 2048).unwrap();
        assert_eq!((r.window, r.stride, r.exclusion), (64, 1, 7));
        assert_eq!(r.k, 3);
    }

    #[test]
    fn search_options_resolve_sharding() {
        // defaults: serial
        let d = SearchOptions::default().resolve(128, 2048).unwrap();
        assert_eq!((d.shards, d.parallelism), (1, 1));
        // explicit shard/thread counts pass through
        let o = SearchOptions { shards: 4, parallelism: 2, ..Default::default() };
        let r = o.resolve(128, 2048).unwrap();
        assert_eq!((r.shards, r.parallelism), (4, 2));
        // shards auto: one per worker thread
        let o = SearchOptions { shards: 0, parallelism: 3, ..Default::default() };
        let r = o.resolve(128, 2048).unwrap();
        assert_eq!((r.shards, r.parallelism), (3, 3));
        // parallelism auto: host parallelism, at least 1
        let o = SearchOptions { shards: 2, parallelism: 0, ..Default::default() };
        let r = o.resolve(128, 2048).unwrap();
        assert_eq!(r.shards, 2);
        assert!(r.parallelism >= 1);
    }

    #[test]
    fn search_options_resolve_validates() {
        // empty query / bad k / oversized window fail up front, in the
        // one resolver every verb shares
        assert!(SearchOptions::default().resolve(0, 2048).is_err());
        let o = SearchOptions { k: 0, ..Default::default() };
        assert!(o.resolve(128, 2048).is_err());
        let o = SearchOptions { window: 4096, ..Default::default() };
        assert!(o.resolve(128, 2048).is_err());
    }

    #[test]
    fn search_options_resolve_band_collapses_to_effective() {
        // a radius covering the window is the unconstrained search
        let o = SearchOptions { window: 64, band: 64, ..Default::default() };
        assert_eq!(o.resolve(128, 2048).unwrap().band, 0);
        let o = SearchOptions { window: 64, band: 63, ..Default::default() };
        assert_eq!(o.resolve(128, 2048).unwrap().band, 63);
        // cascade_opts carries the same resolution (idempotent mapping)
        assert_eq!(o.resolve(128, 2048).unwrap().cascade_opts().band, 63);
    }

    #[test]
    fn options_default_is_exact_f32() {
        let o = AlignOptions::default();
        assert!(!o.pruned && !o.quantized && !o.half);
    }

    #[test]
    fn request_reply_roundtrip() {
        let (tx, rx) = mpsc::sync_channel(1);
        let req = AlignRequest {
            id: 7,
            query: vec![1.0, 2.0],
            options: AlignOptions::default(),
            submitted: Instant::now(),
            reply: tx,
        };
        req.reply
            .send(Ok(AlignResponse {
                id: req.id,
                cost: 0.5,
                end: 3,
                latency_ms: 1.0,
                variant: "v".into(),
            }))
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.end, 3);
    }
}
