//! Dynamic batching: assemble fixed-size kernel batches from a request
//! stream under a latency deadline.
//!
//! The compiled kernels take a *static* batch size B (XLA shapes are
//! static, exactly like the paper's fixed 512×2000 launch geometry), so
//! the batcher's policy space is:
//!   * dispatch as soon as B requests are waiting ("size trigger"), or
//!   * dispatch a partial batch once the oldest request has waited
//!     `deadline` ("deadline trigger"), padding the remaining rows.
//! Padding rows are zero queries whose results are discarded; the
//! padding fraction is tracked by metrics and benched by
//! `ablation_batching`.

use std::time::{Duration, Instant};

use super::request::AlignRequest;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Kernel batch size B (from the variant's manifest entry).
    pub batch_size: usize,
    /// Max wait from the oldest queued request to dispatch.
    pub deadline: Duration,
}

impl BatchPolicy {
    pub fn new(batch_size: usize, deadline: Duration) -> Self {
        assert!(batch_size >= 1);
        Self { batch_size, deadline }
    }
}

/// An assembled batch headed for a worker.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<AlignRequest>,
    /// Rows of padding added to reach the kernel's static batch size.
    pub padding: usize,
    /// When assembly completed (for queue-time metrics).
    pub assembled: Instant,
}

impl Batch {
    pub fn real(&self) -> usize {
        self.requests.len()
    }
}

/// Pure batch-assembly state machine (decisions only — IO-free and unit
/// testable; the dispatcher loop feeds it).
#[derive(Debug)]
pub struct BatchAssembler {
    policy: BatchPolicy,
    pending: Vec<AlignRequest>,
    oldest: Option<Instant>,
}

/// What the dispatcher should do next.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Wait up to this long for another request.
    WaitFor(Duration),
    /// Dispatch now.
    Dispatch,
    /// Nothing pending: block indefinitely for the next request.
    Idle,
}

impl BatchAssembler {
    pub fn new(policy: BatchPolicy) -> Self {
        Self { policy, pending: Vec::with_capacity(policy.batch_size), oldest: None }
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Add a request; returns `Dispatch` if the size trigger fired.
    pub fn offer(&mut self, req: AlignRequest, now: Instant) -> Step {
        if self.pending.is_empty() {
            self.oldest = Some(now);
        }
        self.pending.push(req);
        self.next_step(now)
    }

    /// Decide the next action at time `now`.
    pub fn next_step(&self, now: Instant) -> Step {
        if self.pending.is_empty() {
            return Step::Idle;
        }
        if self.pending.len() >= self.policy.batch_size {
            return Step::Dispatch;
        }
        let waited = now.duration_since(self.oldest.expect("pending implies oldest"));
        if waited >= self.policy.deadline {
            Step::Dispatch
        } else {
            Step::WaitFor(self.policy.deadline - waited)
        }
    }

    /// Take the assembled batch (caller decided to dispatch).
    pub fn take(&mut self, now: Instant) -> Batch {
        assert!(!self.pending.is_empty(), "nothing to dispatch");
        let requests = std::mem::take(&mut self.pending);
        self.oldest = None;
        let padding = self.policy.batch_size.saturating_sub(requests.len());
        Batch { requests, padding, assembled: now }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::AlignOptions;
    use std::sync::mpsc;

    fn req(id: u64) -> AlignRequest {
        let (tx, _rx) = mpsc::sync_channel(1);
        // keep _rx alive? dropped — sends will fail, fine for these tests
        AlignRequest {
            id,
            query: vec![0.0; 4],
            options: AlignOptions::default(),
            submitted: Instant::now(),
            reply: tx,
        }
    }

    fn policy(b: usize, ms: u64) -> BatchPolicy {
        BatchPolicy::new(b, Duration::from_millis(ms))
    }

    #[test]
    fn size_trigger_dispatches_immediately() {
        let mut a = BatchAssembler::new(policy(2, 1000));
        let t = Instant::now();
        assert_eq!(a.offer(req(1), t), Step::WaitFor(Duration::from_millis(1000)));
        assert_eq!(a.offer(req(2), t), Step::Dispatch);
        let b = a.take(t);
        assert_eq!(b.real(), 2);
        assert_eq!(b.padding, 0);
        assert_eq!(a.pending(), 0);
    }

    #[test]
    fn deadline_trigger_pads() {
        let mut a = BatchAssembler::new(policy(4, 10));
        let t0 = Instant::now();
        a.offer(req(1), t0);
        let later = t0 + Duration::from_millis(11);
        assert_eq!(a.next_step(later), Step::Dispatch);
        let b = a.take(later);
        assert_eq!(b.real(), 1);
        assert_eq!(b.padding, 3);
    }

    #[test]
    fn waitfor_shrinks_with_elapsed() {
        let mut a = BatchAssembler::new(policy(4, 100));
        let t0 = Instant::now();
        a.offer(req(1), t0);
        match a.next_step(t0 + Duration::from_millis(60)) {
            Step::WaitFor(d) => {
                assert!(d <= Duration::from_millis(40), "{d:?}");
                assert!(d >= Duration::from_millis(20), "{d:?}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn idle_when_empty() {
        let a = BatchAssembler::new(policy(4, 100));
        assert_eq!(a.next_step(Instant::now()), Step::Idle);
    }

    #[test]
    fn deadline_anchored_to_oldest() {
        // later arrivals must not extend the oldest request's deadline
        let mut a = BatchAssembler::new(policy(8, 50));
        let t0 = Instant::now();
        a.offer(req(1), t0);
        a.offer(req(2), t0 + Duration::from_millis(45));
        assert_eq!(a.next_step(t0 + Duration::from_millis(51)), Step::Dispatch);
    }

    #[test]
    fn order_preserved() {
        let mut a = BatchAssembler::new(policy(3, 100));
        let t = Instant::now();
        a.offer(req(10), t);
        a.offer(req(11), t);
        a.offer(req(12), t);
        let b = a.take(t);
        let ids: Vec<_> = b.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![10, 11, 12]);
    }

    #[test]
    #[should_panic(expected = "nothing to dispatch")]
    fn take_empty_panics() {
        BatchAssembler::new(policy(2, 10)).take(Instant::now());
    }
}
