//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports the usual conventions: `--flag`, `--key value`, `--key=value`,
//! positional arguments, subcommands, `--help` text generation, and typed
//! accessors with good error messages.  The `sdtw` launcher defines its
//! subcommands on top of this in `main.rs`.

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown option --{0}")]
    UnknownOption(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{opt}: {val:?} ({why})")]
    BadValue { opt: String, val: String, why: String },
    #[error("unexpected positional argument {0:?}")]
    UnexpectedPositional(String),
    #[error("missing required option --{0}")]
    MissingRequired(String),
}

/// Declaration of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    /// true if the option takes a value; false = boolean flag
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// A small declarative command parser.
#[derive(Clone, Debug, Default)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    /// names of accepted positionals, for help text only
    positionals: Vec<&'static str>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new(), positionals: Vec::new() }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: false, help, default: None });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, help, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, takes_value: true, help, default: Some(default) });
        self
    }

    pub fn positional(mut self, name: &'static str) -> Self {
        self.positionals.push(name);
        self
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse a raw argument list (without argv[0]/subcommand).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(raw) = it.next() {
            if let Some(body) = raw.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .spec(name)
                    .ok_or_else(|| CliError::UnknownOption(name.to_string()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.to_string()))?,
                    };
                    args.values.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError::BadValue {
                            opt: name.to_string(),
                            val: inline_val.unwrap(),
                            why: "flag takes no value".into(),
                        });
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                if args.positional.len() >= self.positionals.len() {
                    return Err(CliError::UnexpectedPositional(raw.clone()));
                }
                args.positional.push(raw.clone());
            }
        }
        // install defaults
        for spec in &self.opts {
            if let Some(d) = spec.default {
                args.values.entry(spec.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(args)
    }

    /// Render `--help` text.
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUsage: sdtw {}", self.name, self.about, self.name);
        for p in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push_str(" [options]\n\nOptions:\n");
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            out.push_str(&format!("  --{}{}\n      {}{}\n", o.name, val, o.help, def));
        }
        out
    }
}

impl Args {
    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_required(&self, name: &str) -> Result<&str, CliError> {
        self.get(name)
            .ok_or_else(|| CliError::MissingRequired(name.to_string()))
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|e| CliError::BadValue {
                opt: name.to_string(),
                val: raw.to_string(),
                why: e.to_string(),
            }),
        }
    }

    /// Typed get with default (defaults installed by the spec or caller).
    pub fn get_or<T: std::str::FromStr>(&self, name: &str, fallback: T) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parsed(name)?.unwrap_or(fallback))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("gen", "generate a dataset")
            .opt_default("batch", "8", "queries per batch")
            .opt("seed", "rng seed")
            .flag("quick", "fast mode")
            .positional("out")
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = cmd()
            .parse(&argv(&["--batch", "32", "--quick", "file.bin"]))
            .unwrap();
        assert_eq!(a.get("batch"), Some("32"));
        assert!(a.has("quick"));
        assert_eq!(a.positional, vec!["file.bin"]);
    }

    #[test]
    fn equals_syntax() {
        let a = cmd().parse(&argv(&["--batch=64"])).unwrap();
        assert_eq!(a.get_parsed::<usize>("batch").unwrap(), Some(64));
    }

    #[test]
    fn defaults_installed() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.get("batch"), Some("8"));
        assert_eq!(a.get("seed"), None);
    }

    #[test]
    fn typed_access_and_errors() {
        let a = cmd().parse(&argv(&["--batch", "not_a_number"])).unwrap();
        assert!(a.get_parsed::<usize>("batch").is_err());
        assert_eq!(a.get_or("seed", 7u64).unwrap(), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--nope"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["--seed"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn extra_positional_rejected() {
        assert!(matches!(
            cmd().parse(&argv(&["a", "b"])),
            Err(CliError::UnexpectedPositional(_))
        ));
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--quick=yes"])).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--batch"));
        assert!(h.contains("default: 8"));
        assert!(h.contains("<out>"));
    }
}
