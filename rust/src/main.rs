//! `sdtw` — the launcher binary.
//!
//! Subcommands:
//!   gen      generate a synthetic dataset (paper §4's generator)
//!   align    run a dataset through the serving stack, verify vs the CPU
//!            oracle, print metrics
//!   search   top-K subsequence search with the lower-bound cascade
//!            (CPU engine; no artifacts needed)
//!   stream   append-only streaming search: grow the reference in chunks
//!            through the incremental index, delta-search after each
//!            append (CPU engine; no artifacts needed)
//!   serve    start the TCP server over a generated reference
//!   sweep    regenerate the Figure-3 segment-width series
//!   inspect  list the artifact manifest
//!   trace    fetch recent trace spans from a running server
//!   metrics  fetch metrics from a running server (JSON or Prometheus)
//!
//! `sdtw <cmd> --help` prints per-command options.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use sdtw_repro::cli::Command;
use sdtw_repro::config::{ConfigDoc, ServeConfig};
use sdtw_repro::coordinator::{AlignOptions, SdtwService, SearchOptions, ServiceOptions};
use sdtw_repro::datagen::{self, GenConfig};
use sdtw_repro::dtw::{self, Dist};
use sdtw_repro::normalize;
use sdtw_repro::obs;
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::server::{Client, Reactor, ReactorOptions, Response, Server};
use sdtw_repro::util::logger;
use sdtw_repro::log_info;
use sdtw_repro::util::stats::Protocol;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Vec<String>) -> Result<()> {
    // SDTW_LOG accepts a bare level ("debug") or a filter spec with
    // per-target overrides ("info,sdtw::search=trace").
    if let Ok(spec) = std::env::var("SDTW_LOG") {
        if let Err(e) = logger::set_spec(&spec) {
            eprintln!("warning: ignoring SDTW_LOG: {e}");
        }
    }
    obs::init_from_env();
    let (cmd, rest) = match args.split_first() {
        Some((c, rest)) => (c.as_str(), rest.to_vec()),
        None => {
            print_usage();
            return Ok(());
        }
    };
    match cmd {
        "gen" => cmd_gen(rest),
        "align" => cmd_align(rest),
        "search" => cmd_search(rest),
        "stream" => cmd_stream(rest),
        "serve" => cmd_serve(rest),
        "sweep" => cmd_sweep(rest),
        "inspect" => cmd_inspect(rest),
        "trace" => cmd_trace(rest),
        "metrics" => cmd_metrics(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command {other:?}; try `sdtw help`"),
    }
}

fn print_usage() {
    println!(
        "sdtw — subsequence-DTW serving stack (paper reproduction)\n\n\
         Commands:\n\
         \x20 gen      generate a synthetic dataset\n\
         \x20 align    align a dataset through the serving stack\n\
         \x20 search   top-K subsequence search (lower-bound cascade)\n\
         \x20 stream   append-only streaming search (incremental index)\n\
         \x20 serve    start the TCP server\n\
         \x20 sweep    segment-width sweep (Figure 3)\n\
         \x20 inspect  list artifact variants\n\
         \x20 trace    fetch recent trace spans from a running server\n\
         \x20 metrics  fetch metrics from a running server (JSON or Prometheus)\n\n\
         Run `sdtw <command> --help` for options."
    );
}

fn maybe_help(cmd: &Command, raw: &[String]) -> bool {
    if raw.iter().any(|a| a == "--help" || a == "-h") {
        println!("{}", cmd.help());
        true
    } else {
        false
    }
}

// ---------------------------------------------------------------- gen

fn cmd_gen(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("gen", "generate a synthetic dataset (paper §4)")
        .opt_default("batch", "8", "queries in the batch")
        .opt_default("qlen", "128", "query length")
        .opt_default("reflen", "2048", "reference length")
        .opt_default("seed", "42", "rng seed")
        .opt_default("family", "cbf", "workload family: cbf|walk|ecg")
        .opt_default("planted", "0.5", "fraction of queries planted in the reference")
        .opt_default("noise", "0.05", "noise added to planted queries")
        .opt_default("out", "dataset.sdtw", "output file");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;
    let family = datagen::Family::from_name(a.get("family").unwrap())
        .context("family must be cbf|walk|ecg")?;
    let cfg = GenConfig {
        batch: a.get_or("batch", 8usize)?,
        qlen: a.get_or("qlen", 128usize)?,
        reflen: a.get_or("reflen", 2048usize)?,
        seed: a.get_or("seed", 42u64)?,
        planted_fraction: a.get_or("planted", 0.5f64)?,
        noise: a.get_or("noise", 0.05f64)?,
        family,
    };
    let ds = datagen::generate(&cfg);
    let out = PathBuf::from(a.get("out").unwrap());
    datagen::io::write_dataset(&ds, &out)?;
    println!(
        "wrote {}: {} queries × {} vs reference {} ({} planted)",
        out.display(),
        ds.batch(),
        ds.qlen,
        ds.reference.len(),
        ds.truth.iter().filter(|t| t.is_some()).count()
    );
    Ok(())
}

// -------------------------------------------------------------- align

fn cmd_align(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("align", "align a dataset through the serving stack")
        .opt_default("artifacts", "artifacts", "artifacts directory")
        .opt("dataset", "dataset file from `sdtw gen` (default: generate ad hoc)")
        .opt_default("variant", "pipeline_b8_m128_n2048_w16", "pipeline variant")
        .opt_default("workers", "1", "engine workers")
        .opt_default("deadline-ms", "5", "batch deadline (ms)")
        .flag("pruned", "route to the pruned kernel")
        .flag("half", "route to the reduced-precision kernel")
        .flag("quantized", "route to the quantized pipeline")
        .flag("verify", "cross-check against the CPU oracle");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;

    let artifacts = PathBuf::from(a.get("artifacts").unwrap());
    let variant = a.get("variant").unwrap().to_string();
    let manifest = Manifest::load(&artifacts)?;
    let meta = manifest.require(&variant)?.clone();
    let reflen = meta.reflen.context("variant must be an alignment kind")?;

    let ds = match a.get("dataset") {
        Some(path) => datagen::io::read_dataset(std::path::Path::new(path))?,
        None => datagen::generate(&GenConfig {
            batch: meta.batch,
            qlen: meta.qlen,
            reflen,
            ..Default::default()
        }),
    };
    anyhow::ensure!(ds.qlen == meta.qlen, "dataset qlen {} != variant {}", ds.qlen, meta.qlen);
    anyhow::ensure!(
        ds.reference.len() == reflen,
        "dataset reflen {} != variant {}",
        ds.reference.len(),
        reflen
    );

    let opts = ServiceOptions {
        artifacts_dir: artifacts,
        variant,
        batch_deadline: Duration::from_secs_f64(a.get_or("deadline-ms", 5.0f64)? / 1e3),
        workers: a.get_or("workers", 1usize)?,
        ..Default::default()
    };
    let service = SdtwService::start(opts, ds.reference.clone())?;
    let align_opts = AlignOptions {
        pruned: a.has("pruned"),
        half: a.has("half"),
        quantized: a.has("quantized"),
    };

    let queries: Vec<Vec<f32>> = (0..ds.batch()).map(|i| ds.query(i).to_vec()).collect();
    let t0 = std::time::Instant::now();
    let responses = service.align_many(&queries, align_opts)?;
    let wall = t0.elapsed().as_secs_f64() * 1e3;

    for (i, r) in responses.iter().enumerate() {
        let truth = ds.truth[i]
            .map(|e| format!(" (planted @{}..{})", e.start, e.end))
            .unwrap_or_default();
        println!(
            "q{i:3}: cost {:10.4}  end {:6}  {:.2} ms  [{}]{}",
            r.cost, r.end, r.latency_ms, r.variant, truth
        );
    }
    println!("\n{} queries in {:.1} ms; {}", ds.batch(), wall, service.metrics().render());

    if a.has("verify") {
        let rn = normalize::znormed(&ds.reference);
        let mut worst = 0f32;
        for (i, r) in responses.iter().enumerate() {
            let qn = normalize::znormed(ds.query(i));
            let want = dtw::sdtw(&qn, &rn, Dist::Sq);
            let err = (r.cost - want.cost).abs() / want.cost.max(1.0);
            worst = worst.max(err);
            anyhow::ensure!(
                err < 0.05 || align_opts.quantized || align_opts.half,
                "q{i}: service {} vs oracle {}",
                r.cost,
                want.cost
            );
        }
        println!("verify OK (worst relative error {worst:.2e})");
    }
    Ok(())
}

// ------------------------------------------------------------- search

fn cmd_search(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("search", "top-K subsequence search (lower-bound cascade)")
        .opt_default("family", "walk", "reference family: cbf|walk|ecg")
        .opt_default("reflen", "16384", "reference length")
        .opt_default("qlen", "128", "query length")
        .opt_default("k", "5", "match sites to report")
        .opt_default("plant", "3", "warped copies of the query planted in the reference")
        .opt_default("noise", "0.05", "noise added to planted copies")
        .opt_default("seed", "42", "rng seed")
        .opt_default("window", "0", "candidate window length (0 = 3*qlen/2)")
        .opt_default("stride", "1", "candidate stride")
        .opt_default("exclusion", "0", "min distance between reported sites (0 = window/2)")
        .opt_default("shards", "1", "index shards with a shared threshold (0 = one per thread)")
        .opt_default("parallel", "0", "worker threads for sharded search (0 = all cores)")
        .opt_default("kernel", "scalar", "survivor DP kernel: scalar|scan|lanes")
        .opt_default("lanes", "0", "lane count for --kernel lanes (0 = auto)")
        .opt_default("width", "0", "segment width for --kernel scan (0 = auto)")
        .opt_default("lb-kernel", "scalar", "lower-bound prefilter kernel: scalar|block")
        .opt_default("lb-block", "0", "candidates per block for --lb-kernel block (0 = auto)")
        .opt_default("band", "0", "Sakoe-Chiba band radius in samples (0 = unconstrained)")
        .flag("no-cascade", "disable all pruning stages (brute force)")
        .flag("per-shard", "print one stats line per shard")
        .flag("explain", "record and print which stage pruned each sampled candidate")
        .flag("verify", "cross-check hits against brute-force dtw::subsequence top-K");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;

    let family = datagen::Family::from_name(a.get("family").unwrap())
        .context("family must be cbf|walk|ecg")?;
    let reflen: usize = a.get_or("reflen", 16384)?;
    let qlen: usize = a.get_or("qlen", 128)?;
    let k: usize = a.get_or("k", 5)?;
    let plant: usize = a.get_or("plant", 3)?;
    let noise: f64 = a.get_or("noise", 0.05)?;
    let seed: u64 = a.get_or("seed", 42)?;
    anyhow::ensure!(qlen >= 4 && reflen >= 4 * qlen, "need reflen >= 4*qlen and qlen >= 4");

    // workload: a family stream with `plant` warped copies of one query
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(seed);
    let (reference, query, planted) =
        datagen::planted_workload(family, reflen, qlen, plant, noise, &mut rng);

    // one source of truth for "0 = auto" (shared with the service/protocol)
    let kernel_kind = sdtw_repro::dtw::KernelKind::from_name(a.get("kernel").unwrap())
        .context("kernel must be scalar|scan|lanes")?;
    let lb_kind = sdtw_repro::search::LbKernelKind::from_name(a.get("lb-kernel").unwrap())
        .context("lb-kernel must be scalar|block")?;
    let search_options = SearchOptions {
        k,
        window: a.get_or("window", 0usize)?,
        stride: a.get_or("stride", 1usize)?,
        exclusion: a.get_or("exclusion", 0usize)?,
        shards: a.get_or("shards", 1usize)?,
        parallelism: a.get_or("parallel", 0usize)?,
        kernel: kernel_kind,
        lanes: a.get_or("lanes", 0usize)?,
        lb_kernel: lb_kind,
        lb_block: a.get_or("lb-block", 0usize)?,
        band: a.get_or("band", 0usize)?,
        stream: false,
        explain: a.has("explain"),
    };
    let r = search_options.resolve(qlen, reflen)?;
    let (window, stride, exclusion) = (r.window, r.stride, r.exclusion);
    let (shards, parallelism) = (r.shards, r.parallelism);
    // --width is a CLI-only scan refinement on top of the shared spec
    let kernel_spec = sdtw_repro::dtw::KernelSpec {
        width: a.get_or("width", 0usize)?,
        ..r.kernel
    };
    let opts = if a.has("no-cascade") {
        sdtw_repro::search::CascadeOpts::BRUTE
    } else {
        sdtw_repro::search::CascadeOpts::default()
    }
    .with_kernel(kernel_spec)
    .with_lb(r.lb_kernel)
    .with_band(r.band);

    // trace context for this one-shot search: span sampling follows
    // SDTW_TRACE; --explain turns on per-candidate explain events
    let trace_ctx = {
        let ctx = obs::begin_request();
        obs::TraceCtx { explain: ctx.explain || search_options.explain, ..ctx }
    };
    let _obs_guard = obs::enter(trace_ctx);

    let rn = Arc::new(normalize::znormed(&reference));
    let qn = normalize::znormed(&query);
    let t0 = std::time::Instant::now();
    let engine = sdtw_repro::search::SearchEngine::new(rn, window, stride, Dist::Sq)?;
    let build_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = std::time::Instant::now();
    let (out, sharded) = if shards > 1 {
        let so = engine.search_sharded(&qn, k, exclusion, opts, shards, parallelism)?;
        (so.outcome(), Some(so))
    } else {
        (engine.search_opts(&qn, k, exclusion, opts, 1)?, None)
    };
    let search_ms = t1.elapsed().as_secs_f64() * 1e3;

    println!(
        "reference {} ({reflen}) | query {qlen} | window {window} stride {stride} \
         exclusion {exclusion} | {} candidates{}{}",
        a.get("family").unwrap(),
        engine.index().candidates(),
        if shards > 1 {
            format!(" | {shards} shards × {parallelism} threads")
        } else {
            String::new()
        },
        if kernel_kind != sdtw_repro::dtw::KernelKind::Scalar {
            format!(" | kernel {}", kernel_kind.name())
        } else {
            String::new()
        }
    );
    if lb_kind != sdtw_repro::search::LbKernelKind::Scalar {
        println!(
            "lb prefilter: {} kernel, block {}",
            lb_kind.name(),
            match search_options.lb_block {
                0 => "auto".to_string(),
                b => b.to_string(),
            }
        );
    }
    if search_options.band != 0 {
        println!(
            "band: Sakoe-Chiba radius {} (anchored; hits are banded match costs)",
            search_options.band
        );
    }
    for emb in &planted {
        println!("planted copy at {}..{}", emb.start, emb.end);
    }
    println!("\n  rank   start    end        cost");
    for (i, h) in out.hits.iter().enumerate() {
        let near = planted
            .iter()
            .any(|e| h.end >= e.start.saturating_sub(qlen) && h.end <= e.end + qlen);
        println!(
            "  {:4}  {:6}  {:6}  {:10.4}{}",
            i + 1,
            h.start,
            h.end,
            h.cost,
            if near { "  <- planted site" } else { "" }
        );
    }
    let s = out.stats;
    println!(
        "\nindex build {build_ms:.1} ms | search {search_ms:.2} ms | \
         pruned {:.1}% (kim={} keogh={} abandoned={} full_dp={}) | \
         {} survivors in {} kernel batches (occupancy {:.2}) | \
         {} lb blocks (occupancy {:.2}, {} keogh abandons)",
        s.prune_fraction() * 100.0,
        s.pruned_kim,
        s.pruned_keogh,
        s.dp_abandoned,
        s.dp_full,
        s.survivors(),
        s.survivor_batches,
        s.mean_lane_occupancy(),
        s.lb_blocks,
        s.mean_lb_block_occupancy(),
        s.lb_abandons
    );
    if s.pruned_band > 0 || s.band_cells_skipped > 0 {
        println!(
            "band: pruned {} infeasible candidates, skipped {} DP cells",
            s.pruned_band, s.band_cells_skipped
        );
    }
    if let Some(so) = &sharded {
        println!(
            "sharded: {} shards, τ tightened {} times, imbalance {} (slowest/mean)",
            so.shards.len(),
            so.tau_tightenings,
            so.imbalance()
                .map(|r| format!("{r:.2}"))
                .unwrap_or_else(|| "n/a (timings below resolution)".into())
        );
        if a.has("per-shard") {
            for sh in &so.shards {
                println!(
                    "  shard {:3} [{:6}..{:6})  {:8.2} ms  pruned {:5.1}% \
                     (kim={} keogh={} abandoned={} full_dp={})",
                    sh.shard,
                    sh.range.start,
                    sh.range.end,
                    sh.elapsed_ms,
                    sh.stats.prune_fraction() * 100.0,
                    sh.stats.pruned_kim,
                    sh.stats.pruned_keogh,
                    sh.stats.dp_abandoned,
                    sh.stats.dp_full
                );
            }
        }
    }

    if search_options.explain {
        let events = obs::explain_for(trace_ctx.id);
        println!(
            "\nexplain sample: {} candidates (deterministic 1-in-N by candidate id)",
            events.len()
        );
        println!("   start       stage       bound         tau");
        for e in &events {
            println!(
                "  {:6}  {:>10}  {:10.4}  {:10.4}",
                e.start, e.stage, e.bound, e.tau
            );
        }
    }

    if a.has("verify") {
        // brute force inherits the band: banded search verifies against
        // the per-window anchored banded oracle, unbanded against sdtw
        let t2 = std::time::Instant::now();
        let brute_opts = sdtw_repro::search::CascadeOpts::BRUTE.with_band(search_options.band);
        let brute = engine.search_opts(&qn, k, exclusion, brute_opts, 1)?;
        let brute_ms = t2.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(
            out.hits == brute.hits,
            "cascade hits diverge from brute force:\n  cascade: {:?}\n  brute:   {:?}",
            out.hits,
            brute.hits
        );
        println!(
            "verify OK — identical to brute force ({brute_ms:.1} ms; speedup {:.1}x)",
            brute_ms / search_ms.max(1e-9)
        );
    }
    Ok(())
}

// ------------------------------------------------------------- stream

/// Read whitespace-separated floats from a file, or stdin for `-`.
fn read_float_stream(path: &str) -> Result<Vec<f32>> {
    let text = if path == "-" {
        use std::io::Read;
        let mut s = String::new();
        std::io::stdin().read_to_string(&mut s)?;
        s
    } else {
        std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?
    };
    let mut out = Vec::new();
    for (i, tok) in text.split_whitespace().enumerate() {
        out.push(
            tok.parse::<f32>()
                .with_context(|| format!("value {i} ({tok:?}) is not a float"))?,
        );
    }
    anyhow::ensure!(!out.is_empty(), "empty float stream from {path}");
    Ok(out)
}

fn cmd_stream(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new(
        "stream",
        "append-only streaming search: incremental index + delta searches",
    )
    .opt_default("family", "walk", "reference family: cbf|walk|ecg (generated mode)")
    .opt_default("reflen", "16384", "total stream length (generated mode)")
    .opt_default("qlen", "128", "query length (generated mode)")
    .opt_default("k", "5", "match sites to report")
    .opt_default("plant", "3", "warped copies of the query planted in the stream")
    .opt_default("noise", "0.05", "noise added to planted copies")
    .opt_default("seed", "42", "rng seed")
    .opt_default("window", "0", "candidate window length (0 = 3*qlen/2)")
    .opt_default("stride", "1", "candidate stride")
    .opt_default("exclusion", "0", "min distance between reported sites (0 = window/2)")
    .opt_default("chunk", "2048", "samples appended per chunk")
    .opt_default("warmup", "0", "samples indexed before streaming starts (0 = 4*window)")
    .opt_default("kernel", "scalar", "survivor DP kernel: scalar|scan|lanes")
    .opt_default("lanes", "0", "lane count for --kernel lanes (0 = auto)")
    .opt_default("lb-kernel", "scalar", "lower-bound prefilter kernel: scalar|block")
    .opt_default("lb-block", "0", "candidates per block for --lb-kernel block (0 = auto)")
    .opt_default("band", "0", "Sakoe-Chiba band radius in samples (0 = unconstrained)")
    .opt("input", "read the stream from a whitespace-separated float file ('-' = stdin)")
    .opt("query-input", "read the query from a float file (required with --input)")
    .flag("search-each-chunk", "delta-search after every append (default: only at the end)")
    .flag("verify", "assert the final top-K is bit-identical to a one-shot rebuild search");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;

    let k: usize = a.get_or("k", 5)?;
    let seed: u64 = a.get_or("seed", 42)?;
    let noise: f64 = a.get_or("noise", 0.05)?;
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(seed);

    // workload: an explicit float stream, or a generated family stream
    // with planted warped copies (same recipe as `sdtw search`)
    let (reference, query, planted) = match a.get("input") {
        Some(path) => {
            let reference = read_float_stream(path)?;
            let qpath = a
                .get("query-input")
                .context("--input requires --query-input")?;
            let query = read_float_stream(qpath)?;
            (reference, query, Vec::new())
        }
        None => {
            let family = datagen::Family::from_name(a.get("family").unwrap())
                .context("family must be cbf|walk|ecg")?;
            let reflen: usize = a.get_or("reflen", 16384)?;
            let qlen: usize = a.get_or("qlen", 128)?;
            let plant: usize = a.get_or("plant", 3)?;
            anyhow::ensure!(
                qlen >= 4 && reflen >= 4 * qlen,
                "need reflen >= 4*qlen and qlen >= 4"
            );
            datagen::planted_workload(family, reflen, qlen, plant, noise, &mut rng)
        }
    };
    let reflen = reference.len();
    let qlen = query.len();

    let kernel_kind = sdtw_repro::dtw::KernelKind::from_name(a.get("kernel").unwrap())
        .context("kernel must be scalar|scan|lanes")?;
    let lb_kind = sdtw_repro::search::LbKernelKind::from_name(a.get("lb-kernel").unwrap())
        .context("lb-kernel must be scalar|block")?;
    let probe = SearchOptions {
        k,
        window: a.get_or("window", 0usize)?,
        stride: a.get_or("stride", 1usize)?,
        exclusion: a.get_or("exclusion", 0usize)?,
        kernel: kernel_kind,
        lanes: a.get_or("lanes", 0usize)?,
        lb_kernel: lb_kind,
        lb_block: a.get_or("lb-block", 0usize)?,
        band: a.get_or("band", 0usize)?,
        ..Default::default()
    };
    let r = probe.resolve(qlen, reflen)?;
    let (window, stride, exclusion) = (r.window, r.stride, r.exclusion);
    let opts = sdtw_repro::search::CascadeOpts::default()
        .with_kernel(r.kernel)
        .with_lb(r.lb_kernel)
        .with_band(r.band);

    // normalization policy: the offline CLI has the whole stream up
    // front, so it normalizes once with full-stream stats — that is what
    // makes --verify's one-shot rebuild comparison exact.  The *service*
    // instead freezes startup stats for live appends (docs/ARCHITECTURE).
    let rn = normalize::znormed(&reference);
    let qn = normalize::znormed(&query);

    let chunk: usize = a.get_or("chunk", 2048)?;
    anyhow::ensure!(chunk >= 1, "chunk must be >= 1");
    let warmup = {
        let w: usize = a.get_or("warmup", 0)?;
        let w = if w == 0 { 4 * window } else { w };
        w.clamp(window, reflen)
    };

    let mut executors = String::new();
    if kernel_kind != sdtw_repro::dtw::KernelKind::Scalar {
        executors.push_str(&format!(" | kernel {}", kernel_kind.name()));
    }
    if lb_kind != sdtw_repro::search::LbKernelKind::Scalar {
        executors.push_str(&format!(" | lb {}", lb_kind.name()));
    }
    if probe.band != 0 {
        executors.push_str(&format!(" | band {}", probe.band));
    }
    println!(
        "stream {} ({reflen} samples) | query {qlen} | window {window} stride {stride} \
         exclusion {exclusion} | warmup {warmup}, then {}-sample appends{}",
        a.get("input").unwrap_or_else(|| a.get("family").unwrap()),
        chunk,
        executors
    );
    for emb in &planted {
        println!("planted copy at {}..{}", emb.start, emb.end);
    }

    let t0 = std::time::Instant::now();
    let mut engine =
        sdtw_repro::search::StreamingEngine::new(&rn[..warmup], window, stride, Dist::Sq)?;
    let mut appends = 0usize;
    let mut scanned_total = 0u64;
    let mut skipped_total = 0u64;
    let search_each = a.has("search-each-chunk");
    let mut at = warmup;
    while at < reflen {
        let end = (at + chunk).min(reflen);
        engine.append(&rn[at..end]);
        appends += 1;
        at = end;
        if search_each {
            let d = engine.search_delta(&qn, k, exclusion, opts)?;
            scanned_total += d.scanned;
            skipped_total += d.skipped;
            let best = d
                .outcome
                .hits
                .first()
                .map(|h| format!("best {:.4} @{}", h.cost, h.start))
                .unwrap_or_else(|| "no hits".into());
            println!(
                "append {appends:3}: {at:7} samples, {:7} candidates | \
                 delta scanned {:6} skipped {:7} | {best}",
                engine.index().candidates(),
                d.scanned,
                d.skipped
            );
        }
    }
    let d = engine.search_delta(&qn, k, exclusion, opts)?;
    scanned_total += d.scanned;
    skipped_total += d.skipped;
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    let out = &d.outcome;

    println!("\n  rank   start    end        cost");
    for (i, h) in out.hits.iter().enumerate() {
        let near = planted
            .iter()
            .any(|e| h.end >= e.start.saturating_sub(qlen) && h.end <= e.end + qlen);
        println!(
            "  {:4}  {:6}  {:6}  {:10.4}{}",
            i + 1,
            h.start,
            h.end,
            h.cost,
            if near { "  <- planted site" } else { "" }
        );
    }
    println!(
        "\n{} appends + searches in {total_ms:.1} ms | {} candidates indexed | \
         delta passes scanned {scanned_total} and skipped {skipped_total} candidates",
        appends,
        engine.index().candidates()
    );

    if a.has("verify") {
        // one-shot rebuild over the final stream: the streaming result
        // must be bit-identical (hits and candidate count)
        let t1 = std::time::Instant::now();
        let batch =
            sdtw_repro::search::SearchEngine::new(Arc::new(rn.clone()), window, stride, Dist::Sq)?;
        let brute = batch.search_opts(&qn, k, exclusion, opts, 1)?;
        let rebuild_ms = t1.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(
            batch.index().candidates() == engine.index().candidates(),
            "candidate count diverged: streaming {} vs rebuild {}",
            engine.index().candidates(),
            batch.index().candidates()
        );
        anyhow::ensure!(
            out.hits == brute.hits,
            "streaming top-K diverged from one-shot rebuild:\n  stream: {:?}\n  rebuild: {:?}",
            out.hits,
            brute.hits
        );
        println!("verify OK — bit-identical to a one-shot rebuild ({rebuild_ms:.1} ms)");
    }
    Ok(())
}

// -------------------------------------------------------------- serve

fn cmd_serve(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("serve", "start the TCP alignment server")
        .opt("config", "TOML config file ([serve] section)")
        .opt("addr", "bind address (overrides config)")
        .opt("variant", "pipeline variant (overrides config)")
        .opt("workers", "engine workers (overrides config)")
        .opt("threads", "reactor executor threads (overrides config)")
        .opt("max-frame", "per-frame byte cap at the socket edge (overrides config)")
        .opt("max-inflight", "pipelined requests per connection (overrides config)")
        .opt(
            "cluster",
            "comma-separated worker addresses host:port,...; makes this server a \
             cluster coordinator that shards search across them (overrides config)",
        )
        .opt_default("seed", "42", "reference generator seed")
        .opt_default("family", "ecg", "reference family: cbf|walk|ecg")
        .opt_default("reflen", "2048", "reference length (--search-only mode)")
        .flag(
            "search-only",
            "serve search/append/trace/metrics without compiled artifacts (align disabled)",
        )
        .flag("blocking", "use the thread-per-connection front end instead of the reactor");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;

    let mut cfg = match a.get("config") {
        Some(path) => ServeConfig::from_doc(&ConfigDoc::load(std::path::Path::new(path))?)?,
        None => ServeConfig::default(),
    };
    if let Some(addr) = a.get("addr") {
        cfg.addr = addr.to_string();
    }
    if let Some(v) = a.get("variant") {
        cfg.variant = v.to_string();
    }
    if let Some(w) = a.get_parsed::<usize>("workers")? {
        cfg.workers = w;
    }
    if let Some(t) = a.get_parsed::<usize>("threads")? {
        cfg.threads = t;
    }
    if let Some(f) = a.get_parsed::<usize>("max-frame")? {
        cfg.max_frame = f;
    }
    if let Some(m) = a.get_parsed::<usize>("max-inflight")? {
        cfg.max_inflight = m;
    }
    if let Some(c) = a.get("cluster") {
        cfg.cluster = c.to_string();
    }
    cfg.validate().map_err(|e| anyhow::anyhow!("{}", e.msg))?;
    if let Err(e) = logger::set_spec(&cfg.log_level) {
        eprintln!("warning: ignoring log_level: {e}");
    }

    let search_only = a.has("search-only");
    let reflen = if search_only {
        a.get_or("reflen", 2048usize)?
    } else {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let meta = manifest.require(&cfg.variant)?;
        meta.reflen.context("variant must be an alignment kind")?
    };
    let family = datagen::Family::from_name(a.get("family").unwrap())
        .context("family must be cbf|walk|ecg")?;
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(a.get_or("seed", 42u64)?);
    let reference = family.series(reflen, &mut rng);
    log_info!(
        "serving a generated {} reference of length {reflen}{}",
        a.get("family").unwrap(),
        if search_only { " (search-only: no artifacts)" } else { "" }
    );

    let mut opts = ServiceOptions::from_config(&cfg);
    opts.search_only = search_only;
    let mut service = SdtwService::start(opts, reference)?;
    if !cfg.cluster.is_empty() {
        let addrs: Vec<String> = cfg
            .cluster
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        anyhow::ensure!(!addrs.is_empty(), "--cluster needs at least one worker address");
        service.attach_cluster(&addrs)?;
        println!("cluster coordinator over {} worker node(s): {}", addrs.len(), addrs.join(", "));
    }
    let service = Arc::new(service);
    if a.has("blocking") {
        let mut server = Server::bind(service, &cfg.addr)?;
        server.set_max_frame(cfg.max_frame);
        println!("listening on {} — Ctrl-C to stop", server.local_addr()?);
        return server.serve();
    }
    let reactor = Reactor::bind(
        service,
        &cfg.addr,
        ReactorOptions {
            threads: cfg.threads,
            max_frame: cfg.max_frame,
            max_inflight: cfg.max_inflight,
        },
    )?;
    println!(
        "listening on {} ({} executor threads) — Ctrl-C to stop",
        reactor.local_addr()?,
        cfg.threads
    );
    reactor.serve()
}

// -------------------------------------------------------------- sweep

fn cmd_sweep(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("sweep", "segment-width sweep (paper Figure 3)")
        .opt_default("artifacts", "artifacts", "artifacts directory")
        .opt_default("seed", "42", "workload seed")
        .flag("quick", "1 warmup + 3 runs instead of the paper protocol");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;
    let protocol = if a.has("quick") { Protocol::QUICK } else { Protocol::PAPER };
    let table = sdtw_repro::experiments::fig3_sweep(
        &PathBuf::from(a.get("artifacts").unwrap()),
        a.get_or("seed", 42u64)?,
        protocol,
    )?;
    table.print();
    Ok(())
}

// ------------------------------------------------------------ inspect

fn cmd_inspect(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("inspect", "list the artifact manifest")
        .opt_default("artifacts", "artifacts", "artifacts directory");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;
    let manifest = Manifest::load(&PathBuf::from(a.get("artifacts").unwrap()))?;
    println!("{} variants in {}:", manifest.variants.len(), manifest.dir.display());
    for v in &manifest.variants {
        println!(
            "  {:38} kind={:<18} B={:<3} M={:<5} N={:<6} w={:<3} dtype={}{}{}{}",
            v.name,
            format!("{:?}", v.kind),
            v.batch,
            v.qlen,
            v.reflen.map(|n| n.to_string()).unwrap_or_else(|| "-".into()),
            v.segment_width.map(|w| w.to_string()).unwrap_or_else(|| "-".into()),
            v.dtype,
            if v.prune_threshold.is_some() { " pruned" } else { "" },
            if v.quantized { " quantized" } else { "" },
            if v.slow { " slow" } else { "" },
        );
    }
    Ok(())
}

// -------------------------------------------------------------- trace

fn cmd_trace(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("trace", "fetch recent trace spans from a running server")
        .opt_default("addr", "127.0.0.1:7071", "server address")
        .opt_default("limit", "0", "max spans to fetch, oldest dropped (0 = everything buffered)");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;
    let mut client = Client::connect(a.get("addr").unwrap())?;
    let spans = client.trace(a.get_or("limit", 0usize)?)?;
    if spans.is_empty() {
        println!("no spans buffered — start the server with SDTW_TRACE=1 (or =n to sample 1/n)");
        return Ok(());
    }
    println!("   trace       stage      start_ms      dur_ms        floats  detail");
    for s in &spans {
        println!(
            "  {:6}  {:>10}  {:12.3}  {:10.4}  {:12}  {}",
            s.trace, s.stage, s.start_ms, s.dur_ms, s.floats, s.detail
        );
    }
    println!("{} spans", spans.len());
    Ok(())
}

// ------------------------------------------------------------ metrics

fn cmd_metrics(raw: Vec<String>) -> Result<()> {
    let cmd = Command::new("metrics", "fetch metrics from a running server")
        .opt_default("addr", "127.0.0.1:7071", "server address")
        .flag("prometheus", "print Prometheus text exposition instead of the JSON fields");
    if maybe_help(&cmd, &raw) {
        return Ok(());
    }
    let a = cmd.parse(&raw)?;
    let mut client = Client::connect(a.get("addr").unwrap())?;
    if a.has("prometheus") {
        print!("{}", client.metrics_prometheus()?);
    } else {
        let m = client.metrics()?;
        println!("{}", Response::Metrics(Box::new(m)).encode());
    }
    Ok(())
}
