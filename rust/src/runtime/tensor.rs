//! Host-side tensors: the data representation that crosses thread
//! boundaries between the coordinator and the PJRT engine thread
//! (`xla::Literal` wraps raw C pointers and is neither `Send` nor
//! `Sync`, so literals are constructed/destructed only on the engine
//! thread).

use anyhow::{bail, Result};

/// Typed element storage.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: dims + data, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<i64>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: &[i64], data: Vec<f32>) -> Result<HostTensor> {
        let want: i64 = dims.iter().product();
        if want as usize != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, want, data.len());
        }
        Ok(HostTensor { dims: dims.to_vec(), data: TensorData::F32(data) })
    }

    pub fn i32(dims: &[i64], data: Vec<i32>) -> Result<HostTensor> {
        let want: i64 = dims.iter().product();
        if want as usize != data.len() {
            bail!("shape {:?} wants {} elements, got {}", dims, want, data.len());
        }
        Ok(HostTensor { dims: dims.to_vec(), data: TensorData::I32(data) })
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", kind_name(other)),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", kind_name(other)),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, got {:?}", kind_name(&other)),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self.data {
            TensorData::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, got {:?}", kind_name(&other)),
        }
    }
}

fn kind_name(d: &TensorData) -> &'static str {
    match d {
        TensorData::F32(_) => "f32",
        TensorData::I32(_) => "i32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(&[2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(&[4], vec![1, 2, 3, 4]).is_ok());
    }

    #[test]
    fn typed_access() {
        let t = HostTensor::f32(&[2], vec![1.0, 2.0]).unwrap();
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(t.as_i32().is_err());
        assert_eq!(t.len(), 2);
        assert_eq!(t.into_f32().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn i32_roundtrip() {
        let t = HostTensor::i32(&[1, 2], vec![7, 9]).unwrap();
        assert_eq!(t.as_i32().unwrap(), &[7, 9]);
        assert!(t.clone().into_f32().is_err());
        assert_eq!(t.into_i32().unwrap(), vec![7, 9]);
    }
}
