//! Artifact manifest: the Rust view of `artifacts/manifest.json` written
//! by `python/compile/aot.py`.  The manifest is the single source of
//! truth for which model variants exist, their static shapes, and their
//! kernel parameters; the router picks variants from here.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Kind of computation a variant implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Batch z-normalizer (paper §5.1).
    Normalizer,
    /// sDTW on pre-normalized inputs (paper §5.2).
    Sdtw,
    /// znorm ∘ sDTW (the serve path).
    Pipeline,
    /// uint8-codebook quantized pipeline (Discussion §8).
    QuantizedPipeline,
}

impl Kind {
    pub fn from_name(s: &str) -> Option<Kind> {
        match s {
            "normalizer" => Some(Kind::Normalizer),
            "sdtw" => Some(Kind::Sdtw),
            "pipeline" => Some(Kind::Pipeline),
            "quantized_pipeline" => Some(Kind::QuantizedPipeline),
            _ => None,
        }
    }

    /// Does this variant take (queries, reference) or just (queries)?
    pub fn takes_reference(self) -> bool {
        !matches!(self, Kind::Normalizer)
    }
}

/// Metadata of one AOT-compiled variant.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    pub name: String,
    pub kind: Kind,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub batch: usize,
    pub qlen: usize,
    /// None for normalizers.
    pub reflen: Option<usize>,
    pub segment_width: Option<usize>,
    pub dtype: String,
    pub prune_threshold: Option<f64>,
    pub quantized: bool,
    /// Marked slow by the AOT driver (paper-μ shapes); benches gate these.
    pub slow: bool,
    /// Set for ablation-matrix variants (e.g. "scan"); excluded from the
    /// default sweep families.
    pub ablation: Option<String>,
    /// Local-scan implementation of the kernel (sdtw kinds).
    pub scan_impl: Option<String>,
}

impl VariantMeta {
    fn from_json(v: &Json) -> Result<VariantMeta> {
        let name = v
            .get("name")
            .and_then(Json::as_str)
            .context("variant missing name")?
            .to_string();
        let kind_s = v
            .get("kind")
            .and_then(Json::as_str)
            .with_context(|| format!("variant {name}: missing kind"))?;
        let kind = Kind::from_name(kind_s)
            .with_context(|| format!("variant {name}: unknown kind {kind_s}"))?;
        let get_usize = |key: &str| -> Option<usize> {
            v.get(key).and_then(Json::as_i64).map(|x| x as usize)
        };
        Ok(VariantMeta {
            file: v
                .get("file")
                .and_then(Json::as_str)
                .with_context(|| format!("variant {name}: missing file"))?
                .to_string(),
            kind,
            batch: get_usize("batch")
                .with_context(|| format!("variant {name}: missing batch"))?,
            qlen: get_usize("qlen")
                .with_context(|| format!("variant {name}: missing qlen"))?,
            reflen: get_usize("reflen"),
            segment_width: get_usize("segment_width"),
            dtype: v
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
            prune_threshold: v.get("prune_threshold").and_then(Json::as_f64),
            quantized: v
                .get("quantized")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            slow: v.get("slow").and_then(Json::as_bool).unwrap_or(false),
            ablation: v
                .get("ablation")
                .and_then(Json::as_str)
                .map(str::to_string),
            scan_impl: v
                .get("scan_impl")
                .and_then(Json::as_str)
                .map(str::to_string),
            name,
        })
    }

    /// Total DP cell updates per batch execution (0 for normalizers).
    pub fn cells(&self) -> u64 {
        match self.reflen {
            Some(n) => (self.batch * self.qlen) as u64 * n as u64,
            None => 0,
        }
    }

    /// The paper's "floatsProcessed": floats in the query batch.
    pub fn floats_processed(&self) -> u64 {
        (self.batch * self.qlen) as u64
    }
}

/// The parsed manifest plus its directory (for resolving files).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: Vec<VariantMeta>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {}", mpath.display()))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let version = root.get("version").and_then(Json::as_i64).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let raw = root
            .get("variants")
            .and_then(Json::as_arr)
            .context("manifest missing variants")?;
        let variants = raw
            .iter()
            .map(VariantMeta::from_json)
            .collect::<Result<Vec<_>>>()?;
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest { dir: dir.to_path_buf(), variants })
    }

    pub fn get(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.iter().find(|v| v.name == name)
    }

    pub fn require(&self, name: &str) -> Result<&VariantMeta> {
        self.get(name).with_context(|| {
            format!(
                "variant {name:?} not in manifest (have: {})",
                self.variants
                    .iter()
                    .map(|v| v.name.as_str())
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })
    }

    pub fn hlo_path(&self, v: &VariantMeta) -> PathBuf {
        self.dir.join(&v.file)
    }

    /// All sdtw variants at the same shape differing only in
    /// segment width — the Figure-3 sweep family.
    pub fn fig3_family(&self) -> Vec<&VariantMeta> {
        let mut out: Vec<&VariantMeta> = self
            .variants
            .iter()
            .filter(|v| {
                v.kind == Kind::Sdtw
                    && v.dtype == "f32"
                    && v.prune_threshold.is_none()
                    && !v.slow
                    && v.ablation.is_none()
            })
            .collect();
        // keep only the modal (batch, qlen, reflen) shape
        let key = |v: &VariantMeta| (v.batch, v.qlen, v.reflen);
        let mut best_shape = None;
        let mut best_count = 0;
        for v in &out {
            let c = out.iter().filter(|w| key(w) == key(v)).count();
            if c > best_count {
                best_count = c;
                best_shape = Some(key(v));
            }
        }
        out.retain(|v| Some(key(v)) == best_shape);
        out.sort_by_key(|v| v.segment_width);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "version": 1,
          "variants": [
            {"name": "znorm_b8_m128", "kind": "normalizer", "file": "znorm_b8_m128.hlo.txt",
             "batch": 8, "qlen": 128, "reflen": null, "segment_width": null,
             "dtype": "f32", "prune_threshold": null},
            {"name": "sdtw_b8_m128_n2048_w2", "kind": "sdtw", "file": "sdtw_b8_m128_n2048_w2.hlo.txt",
             "batch": 8, "qlen": 128, "reflen": 2048, "segment_width": 2,
             "dtype": "f32", "prune_threshold": null},
            {"name": "sdtw_b8_m128_n2048_w16", "kind": "sdtw", "file": "sdtw_b8_m128_n2048_w16.hlo.txt",
             "batch": 8, "qlen": 128, "reflen": 2048, "segment_width": 16,
             "dtype": "f32", "prune_threshold": null},
            {"name": "sdtw_b8_m128_n2048_w16_bf16", "kind": "sdtw", "file": "x.hlo.txt",
             "batch": 8, "qlen": 128, "reflen": 2048, "segment_width": 16,
             "dtype": "bf16", "prune_threshold": null},
            {"name": "pipeline_b8_m128_n2048_w16", "kind": "pipeline", "file": "p.hlo.txt",
             "batch": 8, "qlen": 128, "reflen": 2048, "segment_width": 16,
             "dtype": "f32", "prune_threshold": null},
            {"name": "sdtw_b64_m500_n10000_w25", "kind": "sdtw", "file": "s.hlo.txt",
             "batch": 64, "qlen": 500, "reflen": 10000, "segment_width": 25,
             "dtype": "f32", "prune_threshold": null, "slow": true}
          ]
        }"#
    }

    fn write_sample(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
    }

    #[test]
    fn load_and_lookup() {
        let dir = std::env::temp_dir().join("sdtw_manifest_test1");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 6);
        let v = m.require("sdtw_b8_m128_n2048_w16").unwrap();
        assert_eq!(v.kind, Kind::Sdtw);
        assert_eq!(v.reflen, Some(2048));
        assert_eq!(v.segment_width, Some(16));
        assert_eq!(v.cells(), 8 * 128 * 2048);
        assert_eq!(v.floats_processed(), 8 * 128);
        assert!(m.get("nope").is_none());
        assert!(m.require("nope").is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn fig3_family_excludes_offshapes_dtypes_slow() {
        let dir = std::env::temp_dir().join("sdtw_manifest_test2");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let fam = m.fig3_family();
        let names: Vec<_> = fam.iter().map(|v| v.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["sdtw_b8_m128_n2048_w2", "sdtw_b8_m128_n2048_w16"],
            "f32, non-slow, modal shape only, sorted by width"
        );
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn normalizer_has_no_reference() {
        let dir = std::env::temp_dir().join("sdtw_manifest_test3");
        write_sample(&dir);
        let m = Manifest::load(&dir).unwrap();
        let v = m.require("znorm_b8_m128").unwrap();
        assert_eq!(v.kind, Kind::Normalizer);
        assert!(!v.kind.takes_reference());
        assert_eq!(v.cells(), 0);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let dir = std::env::temp_dir().join("sdtw_manifest_test4");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 9, "variants": []}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
