//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them on the request path.  Python is **never** involved
//! here — the artifacts are HLO text compiled by the `xla` crate's
//! bundled XLA (see /opt/xla-example and DESIGN.md §5).
//!
//! Structure:
//! * [`artifact`] — `manifest.json` model: variant metadata + lookup.
//! * [`tensor`]   — host-side tensors that cross thread boundaries
//!   (`xla::Literal` holds raw pointers and is neither Send nor Sync).
//! * [`engine`]   — a dedicated executor thread owning one
//!   `PjRtClient` and a lazily-compiled executable cache; callers talk to
//!   it through channels and get back host tensors + device-side timing.
//!
//! The coordinator builds one [`engine::Engine`] per worker.

pub mod artifact;
pub mod engine;
pub mod tensor;

pub use artifact::{Manifest, VariantMeta};
pub use engine::{Engine, EngineHandle, ExecResult};
pub use tensor::{HostTensor, TensorData};
