//! The PJRT executor engine: a dedicated thread that owns a
//! `PjRtClient` and a lazily-compiled cache of loaded executables, and
//! serves execute requests over a channel.
//!
//! Why a thread: `xla::Literal`/`PjRtLoadedExecutable` hold raw C
//! pointers (not `Send`/`Sync`), so all PJRT objects live and die on the
//! engine thread; callers exchange [`HostTensor`]s.  XLA's CPU backend
//! parallelizes single executions internally, so one engine thread does
//! not serialize the math — the coordinator still spawns several engines
//! (one per worker) to overlap host-side conversion with device work.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use super::artifact::Manifest;
use super::tensor::{HostTensor, TensorData};
use crate::log_debug;

/// Offline stand-in for the `xla` PJRT FFI crate.
///
/// This facade compiles in **every** configuration — including
/// `RUSTFLAGS="--cfg sdtw_pjrt"`, which CI checks on every push so the
/// PJRT-gated code paths (this engine's callers, the
/// `search::lb_kernel::PjrtLbKernel` seam) can never silently rot.
/// Vendoring the real bindings (ROADMAP "Real PJRT builds in CI")
/// means adding the `xla` dependency and replacing this module's body
/// with `pub use ::xla::*;` — the facade's surface mirrors the crate's,
/// so no caller changes.  Until then `PjRtClient::cpu()` fails fast, so
/// every other method is unreachable — [`Engine::start`] surfaces the
/// error before any caller can submit work, and the serving stack, CPU
/// substrate, and search subsystem stay fully functional.
#[allow(dead_code)]
mod xla {
    use std::fmt;

    #[derive(Debug)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            Err(Error(
                "built without PJRT support (rebuild with --cfg sdtw_pjrt \
                 and the `xla` dependency) — CPU substrate and search paths \
                 remain fully functional"
                    .to_string(),
            ))
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unreachable!("pjrt stub")
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unreachable!("pjrt stub")
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unreachable!("pjrt stub")
        }
    }

    pub struct Literal;

    impl Literal {
        pub fn vec1<T>(_v: &[T]) -> Literal {
            unreachable!("pjrt stub")
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            unreachable!("pjrt stub")
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            unreachable!("pjrt stub")
        }

        pub fn array_shape(&self) -> Result<ArrayShape, Error> {
            unreachable!("pjrt stub")
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            unreachable!("pjrt stub")
        }

        pub fn convert(&self, _ty: PrimitiveType) -> Result<Literal, Error> {
            unreachable!("pjrt stub")
        }
    }

    pub struct ArrayShape;

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            unreachable!("pjrt stub")
        }

        pub fn ty(&self) -> ElementType {
            unreachable!("pjrt stub")
        }
    }

    #[derive(Clone, Copy, Debug)]
    pub enum ElementType {
        F32,
        S32,
        Other,
    }

    #[derive(Clone, Copy, Debug)]
    pub enum PrimitiveType {
        F32,
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            unreachable!("pjrt stub")
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
            unreachable!("pjrt stub")
        }
    }
}

/// Result of one execution.
#[derive(Clone, Debug)]
pub struct ExecResult {
    pub outputs: Vec<HostTensor>,
    /// Device-side wall time of `execute` + transfer, measured on the
    /// engine thread (excludes queueing) — what kernel benches report.
    pub exec_ms: f64,
}

enum Job {
    Execute {
        variant: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::SyncSender<Result<ExecResult>>,
    },
    /// Compile a variant now (warm the cache off the request path).
    Preload {
        variants: Vec<String>,
        reply: mpsc::SyncSender<Result<Vec<String>>>,
    },
    Shutdown,
}

/// Cloneable handle to an [`Engine`].
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<Job>,
}

/// A running engine (joins its thread on drop).
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
}

impl Engine {
    /// Start an engine over the artifacts in `manifest`.
    pub fn start(manifest: Manifest) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<()>>(1);
        let join = std::thread::Builder::new()
            .name("pjrt-engine".to_string())
            .spawn(move || engine_main(manifest, rx, ready_tx))
            .context("spawning engine thread")?;
        ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine { handle: EngineHandle { tx }, join: Some(join) })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Job::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl EngineHandle {
    /// Execute `variant` with the given inputs, blocking for the result.
    pub fn execute(&self, variant: &str, inputs: Vec<HostTensor>) -> Result<ExecResult> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Job::Execute { variant: variant.to_string(), inputs, reply })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Compile the given variants now; returns the compiled names.
    pub fn preload(&self, variants: &[&str]) -> Result<Vec<String>> {
        let (reply, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Job::Preload {
                variants: variants.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }
}

fn engine_main(
    manifest: Manifest,
    rx: mpsc::Receiver<Job>,
    ready: mpsc::SyncSender<Result<()>>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(anyhow!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    let get_exe = |name: &str,
                       cache: &mut HashMap<String, xla::PjRtLoadedExecutable>|
     -> Result<()> {
        if cache.contains_key(name) {
            return Ok(());
        }
        let meta = manifest.require(name)?;
        let path: PathBuf = manifest.hlo_path(meta);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        log_debug!("compiled {name} in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
        cache.insert(name.to_string(), exe);
        Ok(())
    };

    while let Ok(job) = rx.recv() {
        match job {
            Job::Shutdown => break,
            Job::Preload { variants, reply } => {
                let mut done = Vec::new();
                let mut result = Ok(());
                for v in &variants {
                    if let Err(e) = get_exe(v, &mut cache) {
                        result = Err(e);
                        break;
                    }
                    done.push(v.clone());
                }
                let _ = reply.send(result.map(|_| done));
            }
            Job::Execute { variant, inputs, reply } => {
                let out = (|| -> Result<ExecResult> {
                    get_exe(&variant, &mut cache)?;
                    let exe = cache.get(&variant).unwrap();
                    let literals = inputs
                        .iter()
                        .map(to_literal)
                        .collect::<Result<Vec<_>>>()?;
                    let t0 = std::time::Instant::now();
                    let bufs = exe
                        .execute::<xla::Literal>(&literals)
                        .map_err(|e| anyhow!("execute {variant}: {e}"))?;
                    let result = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("transfer {variant}: {e}"))?;
                    let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
                    // aot.py lowers with return_tuple=True: unwrap the tuple
                    let parts = result
                        .to_tuple()
                        .map_err(|e| anyhow!("untuple {variant}: {e}"))?;
                    let outputs = parts
                        .into_iter()
                        .map(from_literal)
                        .collect::<Result<Vec<_>>>()?;
                    Ok(ExecResult { outputs, exec_ms })
                })();
                let _ = reply.send(out);
            }
        }
    }
}

/// HostTensor → Literal (engine thread only).
fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
        TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
    };
    // scalars/1-D pass through; reshape to the declared dims otherwise
    if t.dims.len() == 1 {
        Ok(lit)
    } else {
        lit.reshape(&t.dims)
            .map_err(|e| anyhow!("reshape to {:?}: {e}", t.dims))
    }
}

/// Literal → HostTensor (engine thread only).
fn from_literal(lit: xla::Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("output shape: {e}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    let data = match shape.ty() {
        xla::ElementType::F32 => {
            TensorData::F32(lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))?)
        }
        xla::ElementType::S32 => {
            TensorData::I32(lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e}"))?)
        }
        other => {
            // half/bf16 etc: convert on device representation to f32
            let conv = lit
                .convert(xla::PrimitiveType::F32)
                .map_err(|e| anyhow!("convert {other:?} output to f32: {e}"))?;
            TensorData::F32(conv.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?)
        }
    };
    Ok(HostTensor { dims, data })
}
