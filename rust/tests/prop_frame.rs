//! Property tests for the wire layer: the [`FrameDecoder`] and the
//! incremental JSON parser must be invariant to how the byte stream is
//! chunked, and must agree exactly with the batch implementations
//! (`BufRead::lines`-style splitting, [`Json::parse`],
//! [`Request::parse`]) they replace.  Everything here is deterministic —
//! seeded [`Xoshiro256`], no wall clock — and the iteration counts
//! shrink under Miri so the suite stays in the CI lane's budget.

use sdtw_repro::server::frame::{FrameDecoder, FrameEvent};
use sdtw_repro::server::proto::{Request, RequestId};
use sdtw_repro::util::json::{IncrementalParser, Json};
use sdtw_repro::util::rng::Xoshiro256;

/// A cap no generated line reaches, for tests about framing alone.
const BIG: usize = 1 << 20;

fn iters(full: usize, miri: usize) -> usize {
    if cfg!(miri) {
        miri
    } else {
        full
    }
}

// ------------------------------------------------------------ generators

fn random_request_line(rng: &mut Xoshiro256) -> String {
    let qlen = 1 + rng.below(4) as usize;
    let query: Vec<f32> = (0..qlen).map(|_| rng.next_f32()).collect();
    let req = match rng.below(6) {
        0 => Request::Ping,
        1 => Request::Info,
        2 => Request::Metrics { prometheus: rng.below(2) == 0 },
        3 => Request::Trace { limit: rng.below(5) as usize },
        4 => Request::Align { query, options: Default::default() },
        _ => Request::Search { query, options: Default::default() },
    };
    let id = match rng.below(3) {
        0 => None,
        1 => Some(RequestId::Int(rng.below(1000) as i64)),
        _ => Some(RequestId::Str(format!("client-{}", rng.below(100)))),
    };
    req.encode_with_id(id.as_ref())
}

/// One wire line: mostly real requests, plus garbage, blanks, and JSON
/// that is valid but not a request.
fn random_line(rng: &mut Xoshiro256) -> Vec<u8> {
    match rng.below(8) {
        0 => Vec::new(),
        1 => b"   ".to_vec(),
        2 => b"not json at all".to_vec(),
        3 => b"{\"op\":\"ping\"  trailing".to_vec(),
        4 => format!("[1,2,{}]", rng.below(100)).into_bytes(),
        _ => random_request_line(rng).into_bytes(),
    }
}

fn random_stream(rng: &mut Xoshiro256, lines: usize) -> Vec<u8> {
    let mut out = Vec::new();
    for _ in 0..lines {
        out.extend_from_slice(&random_line(rng));
        if rng.below(4) == 0 {
            out.push(b'\r');
        }
        out.push(b'\n');
    }
    if rng.below(3) == 0 {
        // a trailing partial frame that never completes
        out.extend_from_slice(b"{\"op\":\"pi");
    }
    out
}

// ---------------------------------------------------------------- models

/// What `BufRead::lines` would produce: split on `\n`, strip one
/// trailing `\r`, drop the unterminated tail.
fn model_lines(stream: &[u8]) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let mut cur = Vec::new();
    for &b in stream {
        if b == b'\n' {
            if cur.last() == Some(&b'\r') {
                cur.pop();
            }
            out.push(std::mem::take(&mut cur));
        } else {
            cur.push(b);
        }
    }
    out
}

#[derive(Debug, PartialEq)]
enum Ev {
    Line(Vec<u8>),
    Oversized(u64),
}

/// Decode `stream` feeding chunks whose sizes come from `next_len`.
fn decode(stream: &[u8], cap: usize, mut next_len: impl FnMut() -> usize) -> Vec<Ev> {
    let mut d = FrameDecoder::new(cap);
    let mut i = 0;
    while i < stream.len() {
        let n = next_len().clamp(1, stream.len() - i);
        d.feed(&stream[i..i + n]);
        i += n;
    }
    let mut out = Vec::new();
    while let Some(e) = d.next_event() {
        out.push(match e {
            FrameEvent::Frame(f) => Ev::Line(f.bytes),
            FrameEvent::Oversized { at } => Ev::Oversized(at),
        });
    }
    out
}

fn chunkings(stream: &[u8], cap: usize, rng: &mut Xoshiro256) -> Vec<Vec<Ev>> {
    let mut all = Vec::new();
    for fixed in [1usize, 2, 3, 7, 11, stream.len().max(1)] {
        all.push(decode(stream, cap, || fixed));
    }
    for _ in 0..3 {
        let mut r = Xoshiro256::new(rng.next_u64());
        all.push(decode(stream, cap, move || 1 + r.below(9) as usize));
    }
    all
}

// ----------------------------------------------------------------- tests

#[test]
fn any_chunking_yields_the_same_frames_as_whole_line_splitting() {
    let mut rng = Xoshiro256::new(0xF7A3E);
    for round in 0..iters(50, 5) {
        let stream = random_stream(&mut rng, 1 + rng.below(12) as usize);
        let expect: Vec<Ev> = model_lines(&stream).into_iter().map(Ev::Line).collect();
        for (i, got) in chunkings(&stream, BIG, &mut rng).into_iter().enumerate() {
            assert_eq!(got, expect, "round {round}, chunking {i}");
        }
    }
}

#[test]
fn decoded_requests_are_bit_identical_to_request_parse() {
    let mut rng = Xoshiro256::new(0xBEEF5);
    for _ in 0..iters(40, 4) {
        let stream = random_stream(&mut rng, 1 + rng.below(10) as usize);
        let mut d = FrameDecoder::new(BIG);
        let mut r = Xoshiro256::new(rng.next_u64());
        let mut i = 0;
        while i < stream.len() {
            let n = (1 + r.below(9) as usize).min(stream.len() - i);
            d.feed(&stream[i..i + n]);
            i += n;
        }
        while let Some(e) = d.next_event() {
            let FrameEvent::Frame(frame) = e else {
                panic!("no oversized frames under BIG cap")
            };
            let line = frame.line().expect("generated streams are utf-8");
            if line.trim().is_empty() {
                continue;
            }
            let classic = Request::parse(line);
            match frame.json {
                Ok(v) => match (Request::from_json(&v), classic) {
                    (Ok(a), Ok(b)) => assert_eq!(a, b, "line {line:?}"),
                    (Err(a), Err(b)) => {
                        assert_eq!(format!("{a:#}"), format!("{b:#}"), "line {line:?}")
                    }
                    (a, b) => panic!("divergence on {line:?}: {a:?} vs {b:?}"),
                },
                Err(_) => assert!(
                    classic.is_err(),
                    "incremental rejected what Request::parse accepts: {line:?}"
                ),
            }
        }
    }
}

#[test]
fn oversized_rejection_is_deterministic_across_chunkings() {
    let cap = 48;
    let mut rng = Xoshiro256::new(0x5EED0);
    for round in 0..iters(40, 4) {
        // interleave short lines with floods past the cap
        let mut stream = Vec::new();
        for _ in 0..1 + rng.below(6) {
            if rng.below(2) == 0 {
                stream.extend_from_slice(random_request_line(&mut rng).as_bytes());
            } else {
                let flood = cap + 1 + rng.below(2 * cap as u64) as usize;
                stream.extend_from_slice(&vec![b'z'; flood]);
            }
            stream.push(b'\n');
        }
        let reference = decode(&stream, cap, || stream.len());
        assert!(
            reference.iter().any(|e| matches!(e, Ev::Oversized(_)))
                || !stream.contains(&b'z'),
            "round {round}: flood rounds must trip the cap"
        );
        for (i, got) in chunkings(&stream, cap, &mut rng).into_iter().enumerate() {
            assert_eq!(got, reference, "round {round}, chunking {i}");
        }
    }
}

// ------------------------------------------- incremental JSON equivalence

fn gen_json_string(rng: &mut Xoshiro256) -> String {
    let mut s = String::from("\"");
    for _ in 0..rng.below(8) {
        match rng.below(8) {
            0 => s.push_str("\\\""),
            1 => s.push_str("\\\\"),
            2 => s.push_str("\\n"),
            3 => s.push_str("\\u0041"),
            4 => s.push('é'),
            _ => s.push((b'a' + rng.below(26) as u8) as char),
        }
    }
    s.push('"');
    s
}

fn gen_json(rng: &mut Xoshiro256, depth: usize) -> String {
    let top = if depth == 0 { 5 } else { 7 };
    match rng.below(top) {
        0 => "null".to_string(),
        1 => if rng.below(2) == 0 { "true" } else { "false" }.to_string(),
        2 => (rng.next_u64() as i64 % 100_000).to_string(),
        3 => format!("{:?}", rng.uniform(-1e6, 1e6)),
        4 => gen_json_string(rng),
        5 => {
            let items: Vec<String> =
                (0..rng.below(4)).map(|_| gen_json(rng, depth - 1)).collect();
            format!("[{}]", items.join(","))
        }
        _ => {
            let items: Vec<String> = (0..rng.below(4))
                .map(|i| format!("\"k{i}\":{}", gen_json(rng, depth - 1)))
                .collect();
            format!("{{{}}}", items.join(","))
        }
    }
}

/// Random corruption so the error side of the contract is exercised too.
fn corrupt(doc: &str, rng: &mut Xoshiro256) -> String {
    let mut bytes = doc.as_bytes().to_vec();
    if bytes.is_empty() {
        return "x".to_string();
    }
    match rng.below(3) {
        0 => {
            bytes.truncate(rng.below(bytes.len() as u64) as usize);
        }
        1 => {
            let i = rng.below(bytes.len() as u64) as usize;
            bytes.remove(i);
        }
        _ => {
            let junk = b"{}[],:\"truefalse019.eE+- x";
            let i = rng.below(bytes.len() as u64 + 1) as usize;
            bytes.insert(i, junk[rng.below(junk.len() as u64) as usize]);
        }
    }
    // corruption may split a multi-byte char; those streams are exercised
    // at the frame layer, while Json::parse takes &str — keep utf-8 here
    String::from_utf8(bytes).unwrap_or_else(|_| "\"\\u12\"".to_string())
}

fn assert_incremental_equiv(doc: &str, rng: &mut Xoshiro256) {
    let reference = Json::parse(doc);
    for _ in 0..3 {
        let mut p = IncrementalParser::new();
        let bytes = doc.as_bytes();
        let mut i = 0;
        while i < bytes.len() {
            let n = (1 + rng.below(7) as usize).min(bytes.len() - i);
            p.feed(&bytes[i..i + n]);
            i += n;
        }
        match (&reference, p.finish()) {
            (Ok(a), Ok(b)) => {
                assert_eq!(a.to_string(), b.to_string(), "value drift on {doc:?}")
            }
            (Err(_), Err(_)) => {}
            (a, b) => panic!("divergence on {doc:?}: recursive {a:?} vs incremental {b:?}"),
        }
    }
}

#[test]
fn incremental_parser_matches_recursive_on_random_documents() {
    let mut rng = Xoshiro256::new(0xACE01);
    for _ in 0..iters(120, 8) {
        let doc = gen_json(&mut rng, 4);
        assert_incremental_equiv(&doc, &mut rng);
        let bad = corrupt(&doc, &mut rng);
        assert_incremental_equiv(&bad, &mut rng);
    }
}
