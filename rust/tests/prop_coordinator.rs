//! Property tests on coordinator invariants: queue delivery, batcher
//! policy, quantizer monotonicity — all artifact-free (pure logic).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use sdtw_repro::coordinator::batcher::{BatchAssembler, BatchPolicy, Step};
use sdtw_repro::coordinator::queue::BoundedQueue;
use sdtw_repro::coordinator::request::{AlignOptions, AlignRequest};
use sdtw_repro::quant::Codebook;
use sdtw_repro::testutil::check;

fn req(id: u64) -> AlignRequest {
    let (tx, _) = mpsc::sync_channel(1);
    AlignRequest {
        id,
        query: vec![0.0; 4],
        options: AlignOptions::default(),
        submitted: Instant::now(),
        reply: tx,
    }
}

#[test]
fn prop_batcher_never_exceeds_batch_size_and_preserves_order() {
    check(200, 100, |g| {
        let b = g.usize_in(1, 16);
        let deadline = Duration::from_millis(g.usize_in(1, 50) as u64);
        let mut asm = BatchAssembler::new(BatchPolicy::new(b, deadline));
        let n = g.usize_in(1, 64);
        let t0 = Instant::now();
        let mut expected_next = 0u64;
        for id in 0..n as u64 {
            let step = asm.offer(req(id), t0);
            if asm.pending() > b {
                return Err(format!("pending {} > batch {b}", asm.pending()));
            }
            if step == Step::Dispatch {
                let batch = asm.take(t0);
                if batch.real() > b {
                    return Err("overfull batch".into());
                }
                if batch.real() + batch.padding != b {
                    return Err("padding arithmetic wrong".into());
                }
                for r in &batch.requests {
                    if r.id != expected_next {
                        return Err(format!("order broken: {} != {expected_next}", r.id));
                    }
                    expected_next += 1;
                }
            }
        }
        // drain
        if asm.pending() > 0 {
            let batch = asm.take(t0);
            for r in &batch.requests {
                if r.id != expected_next {
                    return Err("tail order broken".into());
                }
                expected_next += 1;
            }
        }
        if expected_next != n as u64 {
            return Err(format!("lost requests: {expected_next} of {n}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_batcher_deadline_never_exceeded_at_decision_time() {
    check(201, 100, |g| {
        let b = g.usize_in(2, 16);
        let dl_ms = g.usize_in(1, 100) as u64;
        let deadline = Duration::from_millis(dl_ms);
        let mut asm = BatchAssembler::new(BatchPolicy::new(b, deadline));
        let t0 = Instant::now();
        asm.offer(req(0), t0);
        // at any time >= deadline, the decision must be Dispatch
        let late = t0 + deadline + Duration::from_millis(1);
        match asm.next_step(late) {
            Step::Dispatch => Ok(()),
            other => Err(format!("deadline passed but {other:?}")),
        }
    })
    .unwrap();
}

#[test]
fn prop_queue_delivers_everything_once_fifo_per_producer() {
    check(202, 20, |g| {
        let cap = g.usize_in(1, 16);
        let n = g.usize_in(1, 200);
        let q = std::sync::Arc::new(BoundedQueue::new(cap));
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..n {
                q2.push(i).unwrap();
            }
            q2.close();
        });
        let mut got = Vec::new();
        while let Some(x) = q.pop() {
            got.push(x);
        }
        producer.join().unwrap();
        if got.len() != n {
            return Err(format!("{} of {n} delivered", got.len()));
        }
        if !got.windows(2).all(|w| w[0] < w[1]) {
            return Err("single-producer FIFO violated".into());
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_codebook_monotone_and_bounded() {
    check(203, 100, |g| {
        let r = g.vec_f32(8, 256);
        let cb = Codebook::from_series(&r, 4.0);
        if cb.hi <= cb.lo {
            return Err("degenerate codebook".into());
        }
        // encode is monotone
        let a = g.f32_in(-10.0, 10.0);
        let b = g.f32_in(-10.0, 10.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        if cb.encode(lo) > cb.encode(hi) {
            return Err(format!("monotonicity broken at {lo}, {hi}"));
        }
        // in-range reconstruction error bounded by half a step
        let x = g.f32_in(cb.lo, cb.hi);
        let err = (cb.decode(cb.encode(x)) - x).abs();
        if err > cb.step() / 2.0 + 1e-5 {
            return Err(format!("reconstruction error {err} > step/2 {}", cb.step() / 2.0));
        }
        Ok(())
    })
    .unwrap();
}
