//! Integration: the PJRT runtime executes real artifacts and matches the
//! Rust CPU oracle — the paper's §6 correctness protocol, across kernel
//! variants.  Requires `make artifacts` (skips cleanly if absent).

use std::path::Path;

use sdtw_repro::dtw::{self, Dist};
use sdtw_repro::normalize;
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::{Engine, HostTensor};
use sdtw_repro::util::rng::Xoshiro256;

fn manifest() -> Option<Manifest> {
    let dir = Path::new("artifacts");
    match Manifest::load(dir) {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }
}

fn workload(b: usize, m: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let mut queries = rng.normal_vec_f32(b * m);
    normalize::znorm_batch(&mut queries, m);
    let reference = normalize::znormed(&rng.normal_vec_f32(n));
    (queries, reference)
}

#[test]
fn sdtw_variant_matches_cpu_oracle() {
    let Some(manifest) = manifest() else { return };
    let meta = manifest.require("sdtw_b8_m128_n2048_w16").unwrap().clone();
    let (queries, reference) = workload(meta.batch, meta.qlen, 2048, 1);

    let engine = Engine::start(manifest).unwrap();
    let out = engine
        .handle()
        .execute(
            &meta.name,
            vec![
                HostTensor::f32(&[8, 128], queries.clone()).unwrap(),
                HostTensor::f32(&[2048], reference.clone()).unwrap(),
            ],
        )
        .unwrap();
    let costs = out.outputs[0].as_f32().unwrap();
    let positions = out.outputs[1].as_i32().unwrap();
    assert!(out.exec_ms > 0.0);

    for i in 0..meta.batch {
        let q = &queries[i * meta.qlen..(i + 1) * meta.qlen];
        let want = dtw::sdtw(q, &reference, Dist::Sq);
        let rel = (costs[i] - want.cost).abs() / want.cost.max(1.0);
        assert!(rel < 1e-4, "q{i}: {} vs {}", costs[i], want.cost);
        assert_eq!(positions[i] as usize, want.end, "q{i} position");
    }
}

#[test]
fn every_fig3_width_agrees_with_oracle() {
    let Some(manifest) = manifest() else { return };
    let family: Vec<_> = manifest.fig3_family().into_iter().cloned().collect();
    assert!(family.len() >= 5, "expected a full sweep family");
    let (queries, reference) = workload(family[0].batch, family[0].qlen, 2048, 2);
    let engine = Engine::start(manifest).unwrap();
    let handle = engine.handle();

    // oracle once
    let m = family[0].qlen;
    let oracle: Vec<_> = (0..family[0].batch)
        .map(|i| dtw::sdtw(&queries[i * m..(i + 1) * m], &reference, Dist::Sq))
        .collect();

    for meta in &family {
        let out = handle
            .execute(
                &meta.name,
                vec![
                    HostTensor::f32(&[meta.batch as i64, m as i64], queries.clone()).unwrap(),
                    HostTensor::f32(&[2048], reference.clone()).unwrap(),
                ],
            )
            .unwrap();
        let costs = out.outputs[0].as_f32().unwrap();
        let positions = out.outputs[1].as_i32().unwrap();
        for (i, want) in oracle.iter().enumerate() {
            let rel = (costs[i] - want.cost).abs() / want.cost.max(1.0);
            assert!(rel < 1e-4, "{} q{i}: {} vs {}", meta.name, costs[i], want.cost);
            assert_eq!(positions[i] as usize, want.end, "{} q{i}", meta.name);
        }
    }
}

#[test]
fn scan_impls_agree_bitwise_ish() {
    let Some(manifest) = manifest() else { return };
    let family: Vec<_> = manifest
        .variants
        .iter()
        .filter(|v| v.ablation.as_deref() == Some("scan") && v.segment_width == Some(16))
        .cloned()
        .collect();
    assert_eq!(family.len(), 3, "three scan impls at w16");
    let (queries, reference) = workload(8, 128, 2048, 3);
    let engine = Engine::start(manifest).unwrap();
    let handle = engine.handle();

    let mut all_costs = Vec::new();
    for meta in &family {
        let out = handle
            .execute(
                &meta.name,
                vec![
                    HostTensor::f32(&[8, 128], queries.clone()).unwrap(),
                    HostTensor::f32(&[2048], reference.clone()).unwrap(),
                ],
            )
            .unwrap();
        all_costs.push(out.outputs[0].as_f32().unwrap().to_vec());
    }
    for other in &all_costs[1..] {
        for (a, b) in all_costs[0].iter().zip(other) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{a} vs {b}");
        }
    }
}

#[test]
fn normalizer_artifact_matches_formula() {
    let Some(manifest) = manifest() else { return };
    let meta = manifest.require("znorm_b8_m128").unwrap().clone();
    let mut rng = Xoshiro256::new(4);
    let raw: Vec<f32> = (0..meta.batch * meta.qlen)
        .map(|_| rng.normal_ms(5.0, 3.0) as f32)
        .collect();
    let engine = Engine::start(manifest).unwrap();
    let out = engine
        .handle()
        .execute(
            &meta.name,
            vec![HostTensor::f32(&[8, 128], raw.clone()).unwrap()],
        )
        .unwrap();
    let got = out.outputs[0].as_f32().unwrap();
    let mut want = raw;
    normalize::znorm_batch(&mut want, meta.qlen);
    for (g, w) in got.iter().zip(&want) {
        assert!((g - w).abs() < 1e-3, "{g} vs {w}");
    }
}

#[test]
fn pruned_artifact_inf_semantics() {
    let Some(manifest) = manifest() else { return };
    let meta = manifest
        .require("sdtw_b8_m128_n2048_w16_pruned")
        .unwrap()
        .clone();
    let threshold = meta.prune_threshold.unwrap() as f32;
    // far-apart data: all-zeros queries vs far reference → all pruned
    let queries = vec![0f32; 8 * 128];
    let reference = vec![100f32; 2048];
    let engine = Engine::start(manifest).unwrap();
    let out = engine
        .handle()
        .execute(
            &meta.name,
            vec![
                HostTensor::f32(&[8, 128], queries).unwrap(),
                HostTensor::f32(&[2048], reference).unwrap(),
            ],
        )
        .unwrap();
    let costs = out.outputs[0].as_f32().unwrap();
    assert!(
        costs.iter().all(|c| c.is_infinite() && *c > 0.0),
        "all paths pruned at threshold {threshold}: {costs:?}"
    );
}

#[test]
fn engine_preload_and_unknown_variant() {
    let Some(manifest) = manifest() else { return };
    let engine = Engine::start(manifest).unwrap();
    let handle = engine.handle();
    let loaded = handle.preload(&["znorm_b8_m128"]).unwrap();
    assert_eq!(loaded, vec!["znorm_b8_m128".to_string()]);
    assert!(handle.preload(&["no_such_variant"]).is_err());
    assert!(handle
        .execute("no_such_variant", vec![])
        .is_err());
}

#[test]
fn engine_rejects_bad_input_shape() {
    let Some(manifest) = manifest() else { return };
    let engine = Engine::start(manifest).unwrap();
    // wrong arity
    let r = engine.handle().execute(
        "sdtw_b8_m128_n2048_w16",
        vec![HostTensor::f32(&[8, 128], vec![0.0; 8 * 128]).unwrap()],
    );
    assert!(r.is_err(), "missing reference input must error");
}
