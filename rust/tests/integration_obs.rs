//! Integration: the observability surfaces over a real socket, served
//! by a **search-only** service (no compiled artifacts needed — unlike
//! `integration_server.rs`, these tests never skip).  Covers the
//! `explain` flag end-to-end, the `trace` protocol verb, Prometheus
//! text exposition, and align's fail-fast error in search-only mode.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use sdtw_repro::coordinator::{
    AlignOptions, AppendOptions, SdtwService, SearchOptions, ServiceOptions,
};
use sdtw_repro::obs;
use sdtw_repro::server::{Client, Server};
use sdtw_repro::util::rng::Xoshiro256;

// The trace mode and span rings are process-global and every test here
// runs its own in-process server thread; tests that enable tracing (or
// assert on buffered spans) serialize on this lock and restore the
// prior mode so the others keep running traced-off.
static OBS_LOCK: Mutex<()> = Mutex::new(());

struct TestServer {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn start(reflen: usize) -> TestServer {
        let mut rng = Xoshiro256::new(42);
        let service = Arc::new(
            SdtwService::start(
                ServiceOptions { search_only: true, ..Default::default() },
                rng.normal_vec_f32(reflen),
            )
            .unwrap(),
        );
        let server = Server::bind(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_flag();
        let join = std::thread::spawn(move || server.serve());
        TestServer { addr, stop, join: Some(join) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[test]
fn search_only_service_serves_info_and_search() {
    let ts = TestServer::start(2048);
    let mut client = Client::connect(&ts.addr).unwrap();
    client.ping().unwrap();
    let (qlen, reflen, batch) = client.info().unwrap();
    assert_eq!((qlen, reflen, batch), (SdtwService::SEARCH_ONLY_QLEN, 2048, 1));

    let mut rng = Xoshiro256::new(5);
    let q = rng.normal_vec_f32(64);
    let s = client.search(&q, SearchOptions { k: 3, ..Default::default() }).unwrap();
    assert!(s.windows > 0);
    assert!(!s.hits.is_empty());
    assert_eq!(
        s.pruned_kim + s.pruned_keogh + s.dp_abandoned + s.skipped + s.dp_full,
        s.windows,
        "counters must partition the candidate space over the wire"
    );
}

#[test]
fn explain_flag_is_inert_over_the_wire() {
    let ts = TestServer::start(2048);
    let mut client = Client::connect(&ts.addr).unwrap();
    let mut rng = Xoshiro256::new(6);
    let q = rng.normal_vec_f32(64);

    let plain = client.search(&q, SearchOptions { k: 3, ..Default::default() }).unwrap();
    let explained = client
        .search(&q, SearchOptions { k: 3, explain: true, ..Default::default() })
        .unwrap();
    assert_eq!(plain.hits.len(), explained.hits.len());
    for (a, b) in plain.hits.iter().zip(&explained.hits) {
        assert_eq!(a.start, b.start);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "explain must be bit-inert");
    }
    assert_eq!(plain.windows, explained.windows);
    assert_eq!(plain.pruned_kim, explained.pruned_kim);
    assert_eq!(plain.pruned_keogh, explained.pruned_keogh);
    assert_eq!(plain.dp_abandoned, explained.dp_abandoned);
    assert_eq!(plain.dp_full, explained.dp_full);
}

#[test]
fn trace_verb_surfaces_spans_for_traced_requests() {
    let _l = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let prev = obs::mode();
    let ts = TestServer::start(1024);
    let mut client = Client::connect(&ts.addr).unwrap();

    // the verb itself works with tracing off — record the watermark
    let before = client.trace(0).unwrap().len();

    obs::set_mode(1);
    let mut rng = Xoshiro256::new(7);
    let q = rng.normal_vec_f32(48);
    let s = client.search(&q, SearchOptions { k: 2, ..Default::default() }).unwrap();
    assert!(s.windows > 0);
    // grow the stream and delta-search it so the streaming stage traces too
    client.append(&rng.normal_vec_f32(512), AppendOptions::default()).unwrap();
    client
        .search(&q, SearchOptions { k: 2, stream: true, ..Default::default() })
        .unwrap();
    obs::set_mode(prev);

    let spans = client.trace(0).unwrap();
    assert!(spans.len() > before, "traced requests must buffer spans");
    assert!(
        spans.iter().any(|sp| sp.stage == "search"),
        "whole-request search span expected: {spans:?}"
    );
    assert!(
        spans.iter().any(|sp| sp.stage == "delta"),
        "streaming delta span expected: {spans:?}"
    );
    assert!(
        spans
            .iter()
            .any(|sp| sp.stage == "envelope" || sp.stage == "keogh" || sp.stage == "dp"),
        "cascade stage spans expected: {spans:?}"
    );
    let newest_search = spans.iter().rev().find(|sp| sp.stage == "search").unwrap();
    assert!(newest_search.trace > 0, "spans must carry the request's trace id");
    assert!(newest_search.dur_ms >= 0.0 && newest_search.start_ms >= 0.0);
    assert!(newest_search.floats > 0, "search spans account floats for Gsps");

    // limit trims to the newest N
    let one = client.trace(1).unwrap();
    assert_eq!(one.len(), 1);
}

#[test]
fn prometheus_exposition_over_the_wire_is_line_formatted() {
    let _l = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    let ts = TestServer::start(512);
    let mut client = Client::connect(&ts.addr).unwrap();
    let mut rng = Xoshiro256::new(8);
    let q = rng.normal_vec_f32(32);
    client.search(&q, SearchOptions { k: 1, ..Default::default() }).unwrap();

    let text = client.metrics_prometheus().unwrap();
    assert!(text.contains("# TYPE sdtw_requests_total counter"));
    assert!(text.lines().any(|l| l.starts_with("sdtw_requests_total ")));
    assert!(text.lines().any(|l| l.starts_with("sdtw_searches_total ")));
    assert!(text.lines().any(|l| l.starts_with("sdtw_latency_ms{quantile=\"0.5\"} ")));
    // every sample line is `name{labels} value` with a parseable,
    // finite value — the python lane re-checks the full grammar
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        let (name, value) = line.rsplit_once(' ').expect("sample line has a value");
        assert!(!name.is_empty(), "empty metric name in {line:?}");
        let v: f64 = value.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        assert!(v.is_finite(), "non-finite value in {line:?}");
    }

    // the JSON metrics verb still works on the same connection
    let m = client.metrics().unwrap();
    assert!(m.searches >= 1);
}

#[test]
fn align_fails_fast_in_search_only_mode() {
    let ts = TestServer::start(256);
    let mut client = Client::connect(&ts.addr).unwrap();
    let err = client
        .align(&[0.0; 128], AlignOptions::default())
        .expect_err("align must be rejected without artifacts");
    assert!(
        err.to_string().contains("search-only"),
        "error should name the mode: {err}"
    );
    // the connection (and the rest of the protocol) survives
    client.ping().unwrap();
}
