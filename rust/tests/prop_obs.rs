//! The observability layer's acceptance invariant: tracing is **inert**.
//! Hits (bit-for-bit, `f32::to_bits`) and cascade counters must be
//! identical whether tracing is off, full, sampled, or in explain mode —
//! on the serial engine, the sharded executor, and the streaming delta
//! path.  The counter partition invariant
//! (`pruned_total() + dp_full == candidates`) is pinned in every mode.
//!
//! The trace mode and the span/explain rings are process-global, so
//! every test here serializes on one lock and restores the prior mode
//! before returning (other integration tests in this binary run with
//! tracing off and must stay that way).

use std::sync::{Arc, Mutex, MutexGuard};

use sdtw_repro::dtw::Dist;
use sdtw_repro::obs;
use sdtw_repro::search::{CascadeOpts, CascadeStats, Hit, SearchEngine, StreamingEngine};
use sdtw_repro::testutil::check;

/// Bit-exact signature of one delta-search step (hits, counters, and
/// the delta accounting — all of which must be mode-invariant).
type DeltaSig = (Vec<(usize, usize, u32)>, CascadeStats, u64, u64);

static OBS_LOCK: Mutex<()> = Mutex::new(());

/// Take the global obs lock and return a guard that restores the prior
/// trace mode (even on panic — the next test must not inherit it).
struct ModeGuard<'a> {
    _lock: MutexGuard<'a, ()>,
    prev: u32,
}

impl Drop for ModeGuard<'_> {
    fn drop(&mut self) {
        obs::set_mode(self.prev);
    }
}

fn lock_obs() -> ModeGuard<'static> {
    let lock = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    ModeGuard { _lock: lock, prev: obs::mode() }
}

/// Random-walk style series (level drift makes envelope bounds bite).
fn walk(g: &mut sdtw_repro::testutil::GenCtx, lo: usize, hi: usize) -> Vec<f32> {
    let base = g.vec_f32(lo, hi);
    let mut level = 0f32;
    base.iter()
        .map(|&step| {
            level += step * 0.5;
            level
        })
        .collect()
}

/// Bit-exact signature of a hit list.
fn sig(hits: &[Hit]) -> Vec<(usize, usize, u32)> {
    hits.iter().map(|h| (h.start, h.end, h.cost.to_bits())).collect()
}

fn check_partition(label: &str, stats: &CascadeStats) -> Result<(), String> {
    if stats.pruned_total() + stats.dp_full != stats.candidates {
        return Err(format!("{label}: counters don't partition candidates: {stats:?}"));
    }
    Ok(())
}

/// The trace/explain configurations every path is checked under.
/// (mode, explain): mode 0 = off, 1 = full, 5 = sample 1-in-5.
const CONFIGS: [(u32, bool); 5] =
    [(0, false), (1, false), (5, false), (0, true), (1, true)];

/// Run `f` under one trace configuration inside a fresh request context
/// (the CLI/server edge in miniature) and return its output.
fn under<T>(mode: u32, explain: bool, f: impl FnOnce() -> T) -> T {
    obs::set_mode(mode);
    let ctx = obs::begin_request();
    let ctx = obs::TraceCtx { explain, ..ctx };
    let _g = obs::enter(ctx);
    f()
}

#[test]
fn prop_serial_search_inert_under_all_trace_modes() {
    let _m = lock_obs();
    check(601, 40, |g| {
        let r = Arc::new(walk(g, 50, 200));
        let m = g.usize_in(3, 12);
        let window = g.usize_in(m, (m + 10).min(r.len()));
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(0, window);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, g.usize_in(1, 3), Dist::Sq)
            .map_err(|e| e.to_string())?;

        let baseline = under(0, false, || engine.search(&q, k, exclusion))
            .map_err(|e| e.to_string())?;
        check_partition("baseline", &baseline.stats)?;
        for (mode, explain) in CONFIGS {
            let out = under(mode, explain, || engine.search(&q, k, exclusion))
                .map_err(|e| e.to_string())?;
            if sig(&out.hits) != sig(&baseline.hits) {
                return Err(format!("mode={mode} explain={explain}: hits diverged"));
            }
            if out.stats != baseline.stats {
                return Err(format!(
                    "mode={mode} explain={explain}: counters diverged: {:?} vs {:?}",
                    out.stats, baseline.stats
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_search_inert_under_all_trace_modes() {
    let _m = lock_obs();
    check(602, 30, |g| {
        let r = Arc::new(walk(g, 60, 220));
        let m = g.usize_in(3, 10);
        let window = g.usize_in(m, (m + 10).min(r.len()));
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(0, window);
        let shards = g.usize_in(2, 8);
        let threads = g.usize_in(1, 4);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;

        let baseline = under(0, false, || {
            engine.search_sharded(&q, k, exclusion, CascadeOpts::default(), shards, threads)
        })
        .map_err(|e| e.to_string())?;
        check_partition("baseline", &baseline.stats)?;
        for (mode, explain) in CONFIGS {
            let out = under(mode, explain, || {
                engine.search_sharded(&q, k, exclusion, CascadeOpts::default(), shards, threads)
            })
            .map_err(|e| e.to_string())?;
            if sig(&out.hits) != sig(&baseline.hits) {
                return Err(format!("mode={mode} explain={explain}: sharded hits diverged"));
            }
            if out.stats != baseline.stats {
                return Err(format!(
                    "mode={mode} explain={explain}: sharded counters diverged: {:?} vs {:?}",
                    out.stats, baseline.stats
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_streaming_delta_inert_under_all_trace_modes() {
    // the delta path is stateful (watermark cache), so each mode gets a
    // fresh StreamingEngine replaying the same append/search schedule
    let _m = lock_obs();
    check(603, 25, |g| {
        let x = walk(g, 60, 240);
        let window = g.usize_in(4, x.len().min(20));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let m = g.usize_in(3, 10);
        let q = g.vec_f32(m, m);
        let seed_len = g.usize_in(window, x.len());
        // pre-draw the append schedule so every replay is identical
        let mut cuts = vec![seed_len];
        while *cuts.last().unwrap() < x.len() {
            let at = *cuts.last().unwrap();
            cuts.push((at + g.usize_in(1, 50)).min(x.len()));
        }

        let replay = |mode: u32, explain: bool| -> Result<Vec<DeltaSig>, String> {
            let mut se = StreamingEngine::new(&x[..seed_len], window, 1, Dist::Sq)
                .map_err(|e| e.to_string())?;
            let mut results = Vec::new();
            for w in cuts.windows(2) {
                se.append(&x[w[0]..w[1]]);
                let d = under(mode, explain, || {
                    se.search_delta(&q, k, exclusion, CascadeOpts::default())
                })
                .map_err(|e| e.to_string())?;
                check_partition(&format!("delta at {}", w[1]), &d.outcome.stats)?;
                results.push((sig(&d.outcome.hits), d.outcome.stats, d.scanned, d.skipped));
            }
            Ok(results)
        };

        let baseline = replay(0, false)?;
        for (mode, explain) in CONFIGS {
            let got = replay(mode, explain)?;
            if got != baseline {
                return Err(format!(
                    "mode={mode} explain={explain}: streaming delta trajectory diverged"
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn explain_mode_records_events_without_perturbing_results() {
    // one deterministic workload: explain on must (a) leave hits and
    // counters untouched and (b) actually record per-candidate events
    // attributable to this request's trace id
    let _m = lock_obs();
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(77);
    let reference: Vec<f32> = {
        let mut level = 0f64;
        (0..3000)
            .map(|_| {
                level += rng.normal() * 0.3;
                level as f32
            })
            .collect()
    };
    let query: Vec<f32> = rng.normal_vec_f32(32);
    let engine = SearchEngine::new(Arc::new(reference), 48, 1, Dist::Sq).unwrap();

    let plain = under(0, false, || engine.search(&query, 3, 24)).unwrap();

    obs::set_mode(0);
    let ctx = obs::begin_request();
    let ctx = obs::TraceCtx { explain: true, ..ctx };
    let explained = {
        let _g = obs::enter(ctx);
        engine.search(&query, 3, 24).unwrap()
    };
    assert_eq!(sig(&plain.hits), sig(&explained.hits), "explain changed the hits");
    assert_eq!(plain.stats, explained.stats, "explain changed the counters");

    let events = obs::explain_for(ctx.id);
    assert!(!events.is_empty(), "explain mode recorded no events");
    let stages: std::collections::BTreeSet<&str> =
        events.iter().map(|e| e.stage).collect();
    for s in &stages {
        assert!(
            ["kim", "keogh", "dp_abandon", "dp_full"].contains(s),
            "unknown explain stage {s:?}"
        );
    }
    // sampled candidate starts must be real candidate positions
    for e in &events {
        assert!(e.start < engine.index().candidates(), "event start out of range");
    }
}

#[test]
fn trace_spans_accumulate_per_stage_without_perturbing_results() {
    // full-trace mode on the sharded path: results identical, and the
    // span ring gains shard + dp spans attributable to this request
    let _m = lock_obs();
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(78);
    let reference: Vec<f32> = {
        let mut level = 0f64;
        (0..2400)
            .map(|_| {
                level += rng.normal() * 0.3;
                level as f32
            })
            .collect()
    };
    let query: Vec<f32> = rng.normal_vec_f32(24);
    let engine = SearchEngine::new(Arc::new(reference), 36, 1, Dist::Sq).unwrap();

    let plain = under(0, false, || {
        engine.search_sharded(&query, 3, 18, CascadeOpts::default(), 4, 2)
    })
    .unwrap();

    obs::set_mode(1);
    let ctx = obs::begin_request();
    assert!(ctx.sampled, "mode 1 must sample every request");
    let traced = {
        let _g = obs::enter(ctx);
        engine.search_sharded(&query, 3, 18, CascadeOpts::default(), 4, 2).unwrap()
    };
    assert_eq!(sig(&plain.hits), sig(&traced.hits), "tracing changed the hits");
    assert_eq!(plain.stats, traced.stats, "tracing changed the counters");

    let spans = obs::recent_spans(usize::MAX);
    let mine: Vec<_> = spans.iter().filter(|s| s.trace_id == ctx.id).collect();
    assert!(!mine.is_empty(), "full-trace mode recorded no spans");
    assert!(
        mine.iter().any(|s| s.stage == obs::Stage::Shard),
        "sharded search must emit shard spans"
    );
    assert!(
        mine.iter().all(|s| s.dur_ms >= 0.0 && s.start_ms >= 0.0),
        "span clocks must be non-negative"
    );
}
