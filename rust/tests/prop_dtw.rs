//! Property tests on DTW invariants, via the in-repo property harness
//! (`testutil`) — the proptest stand-in (DESIGN.md "Session caveats").

use sdtw_repro::dtw::banded::sdtw_banded;
use sdtw_repro::dtw::full::dtw;
use sdtw_repro::dtw::pruned::sdtw_pruned;
use sdtw_repro::dtw::scan::sdtw_scan;
use sdtw_repro::dtw::traceback::sdtw_path;
use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::normalize::znormed;
use sdtw_repro::testutil::check;

#[test]
fn prop_scan_equals_naive_any_width() {
    check(100, 200, |g| {
        let q = g.vec_f32(1, 16);
        let r = g.vec_f32(1, 64);
        let w = g.usize_in(1, 70);
        let a = sdtw(&q, &r, Dist::Sq);
        let b = sdtw_scan(&q, &r, w, Dist::Sq);
        if (a.cost - b.cost).abs() > 1e-3 * a.cost.max(1.0) {
            return Err(format!("w={w}: {} vs {}", a.cost, b.cost));
        }
        if a.end != b.end {
            return Err(format!("w={w}: end {} vs {}", a.end, b.end));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_cost_nonnegative_and_zero_iff_embedded() {
    check(101, 100, |g| {
        let q = g.vec_f32(2, 12);
        let r = g.vec_f32(2, 40);
        let m = sdtw(&q, &r, Dist::Sq);
        if m.cost < 0.0 {
            return Err(format!("negative cost {}", m.cost));
        }
        // embed q verbatim: cost becomes ~0
        let mut r2 = r.clone();
        if r2.len() >= q.len() {
            let at = g.usize_in(0, r2.len() - q.len());
            r2[at..at + q.len()].copy_from_slice(&q);
            let m2 = sdtw(&q, &r2, Dist::Sq);
            if m2.cost > 1e-4 {
                return Err(format!("embedded but cost {}", m2.cost));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_subsequence_le_global_le_euclidean_window() {
    check(102, 100, |g| {
        let q = g.vec_f32(2, 10);
        let r = g.vec_f32(10, 40);
        let s = sdtw(&q, &r, Dist::Sq).cost;
        let f = dtw(&q, &r, Dist::Sq);
        if s > f + 1e-4 {
            return Err(format!("sdtw {s} > dtw {f}"));
        }
        // sdtw <= best lockstep window (band-0 = lockstep window search)
        let b0 = sdtw_banded(&q, &r, 0, Dist::Sq).cost;
        if s > b0 + 1e-4 {
            return Err(format!("sdtw {s} > lockstep-window {b0}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_banded_monotone_and_converges() {
    check(103, 60, |g| {
        let q = g.vec_f32(2, 8);
        let r = g.vec_f32(4, 24);
        let full = sdtw(&q, &r, Dist::Sq).cost;
        let mut prev = f32::INFINITY;
        for band in [0usize, 1, 2, 4, 8, 32] {
            let c = sdtw_banded(&q, &r, band, Dist::Sq).cost;
            if c > prev + 1e-4 {
                return Err(format!("band {band} worsened: {c} > {prev}"));
            }
            if c < full - 1e-4 {
                return Err(format!("band {band} beat unbanded: {c} < {full}"));
            }
            prev = c;
        }
        if (prev - full).abs() > 1e-4 {
            return Err(format!("wide band {prev} != unbanded {full}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_pruned_upper_bound_and_loose_threshold_exact() {
    check(104, 100, |g| {
        let q = g.vec_f32(2, 10);
        let r = g.vec_f32(2, 32);
        let thr = g.f32_in(0.1, 3.0);
        let exact = sdtw(&q, &r, Dist::Sq);
        let p = sdtw_pruned(&q, &r, thr, Dist::Sq);
        if p.cost < exact.cost - 1e-4 {
            return Err(format!("pruned {} < exact {}", p.cost, exact.cost));
        }
        let loose = sdtw_pruned(&q, &r, 1e9, Dist::Sq);
        if (loose.cost - exact.cost).abs() > 1e-5 || loose.pruned_cells != 0 {
            return Err("loose threshold changed result".into());
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_traceback_path_valid_and_consistent() {
    check(105, 80, |g| {
        let q = g.vec_f32(2, 8);
        let r = g.vec_f32(2, 24);
        let (cost, path) = sdtw_path(&q, &r, Dist::Sq);
        let m = sdtw(&q, &r, Dist::Sq);
        if (cost - m.cost).abs() > 1e-4 * m.cost.max(1.0) {
            return Err(format!("path cost {cost} vs oracle {}", m.cost));
        }
        if path.first().map(|&(i, _)| i) != Some(0) {
            return Err("path must start at query row 0".into());
        }
        if path.last() != Some(&(q.len() - 1, m.end)) {
            return Err(format!("path end {:?} vs ({}, {})", path.last(), q.len() - 1, m.end));
        }
        for w in path.windows(2) {
            let (di, dj) = (w[1].0 - w[0].0, w[1].1 as i64 - w[0].1 as i64);
            if !matches!((di, dj), (0, 1) | (1, 0) | (1, 1)) {
                return Err(format!("illegal step {:?} -> {:?}", w[0], w[1]));
            }
        }
        let sum: f32 = path.iter().map(|&(i, j)| Dist::Sq.eval(q[i], r[j])).sum();
        if (sum - cost).abs() > 1e-3 * cost.max(1.0) {
            return Err(format!("path sum {sum} vs cost {cost}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_znorm_affine_invariance_of_sdtw() {
    // sDTW on z-normalized data is invariant to affine transforms of the
    // raw inputs — the reason the paper normalizes at all
    check(106, 60, |g| {
        let q = g.vec_f32(4, 12);
        let r = g.vec_f32(8, 40);
        let scale = g.f32_in(0.5, 20.0);
        let shift = g.f32_in(-10.0, 10.0);
        let q2: Vec<f32> = q.iter().map(|x| x * scale + shift).collect();
        let a = sdtw(&znormed(&q), &znormed(&r), Dist::Sq);
        let b = sdtw(&znormed(&q2), &znormed(&r), Dist::Sq);
        if (a.cost - b.cost).abs() > 1e-2 * a.cost.max(1.0) {
            return Err(format!("affine variance: {} vs {}", a.cost, b.cost));
        }
        if a.end != b.end {
            return Err(format!("end moved: {} vs {}", a.end, b.end));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_query_reversal_symmetry() {
    // reversing BOTH series mirrors the problem: cost is preserved
    check(107, 60, |g| {
        let q = g.vec_f32(2, 10);
        let r = g.vec_f32(2, 30);
        let a = sdtw(&q, &r, Dist::Sq).cost;
        let qr: Vec<f32> = q.iter().rev().cloned().collect();
        let rr: Vec<f32> = r.iter().rev().cloned().collect();
        let b = sdtw(&qr, &rr, Dist::Sq).cost;
        if (a - b).abs() > 1e-3 * a.max(1.0) {
            return Err(format!("reversal asymmetry: {a} vs {b}"));
        }
        Ok(())
    })
    .unwrap();
}
