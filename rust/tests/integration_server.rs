//! Integration: TCP server round-trips over real artifacts — protocol
//! conformance, concurrent connections, malformed input resilience.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use sdtw_repro::coordinator::{
    AlignOptions, AppendOptions, SdtwService, SearchOptions, ServiceOptions,
};
use sdtw_repro::server::{Client, Server};
use sdtw_repro::util::rng::Xoshiro256;

const VARIANT: &str = "pipeline_b8_m128_n2048_w16";

struct TestServer {
    addr: String,
    stop: Arc<std::sync::atomic::AtomicBool>,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn start() -> Option<TestServer> {
        if !Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        let mut rng = Xoshiro256::new(42);
        let service = Arc::new(
            SdtwService::start(
                ServiceOptions {
                    variant: VARIANT.into(),
                    workers: 1,
                    batch_deadline: Duration::from_millis(3),
                    ..Default::default()
                },
                rng.normal_vec_f32(2048),
            )
            .unwrap(),
        );
        let server = Server::bind(service, "127.0.0.1:0").unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let stop = server.stop_flag();
        let join = std::thread::spawn(move || server.serve());
        Some(TestServer { addr, stop, join: Some(join) })
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[test]
fn ping_info_align_metrics_roundtrip() {
    let Some(ts) = TestServer::start() else { return };
    let mut client = Client::connect(&ts.addr).unwrap();
    client.ping().unwrap();

    let (qlen, reflen, batch) = client.info().unwrap();
    assert_eq!((qlen, reflen, batch), (128, 2048, 8));

    let mut rng = Xoshiro256::new(1);
    let q = rng.normal_vec_f32(128);
    let (cost, end, latency_ms) = client.align(&q, AlignOptions::default()).unwrap();
    assert!(cost.is_finite() && cost >= 0.0);
    assert!(end < 2048);
    assert!(latency_ms > 0.0);

    let m = client.metrics().unwrap();
    assert_eq!(m.responses, 1);
    assert!(m.batches >= 1);
}

#[test]
fn concurrent_connections() {
    let Some(ts) = TestServer::start() else { return };
    let mut handles = Vec::new();
    for t in 0..6 {
        let addr = ts.addr.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let mut rng = Xoshiro256::stream(2, t);
            let mut costs = Vec::new();
            for _ in 0..5 {
                let q = rng.normal_vec_f32(128);
                let (cost, _, _) = client.align(&q, AlignOptions::default()).unwrap();
                costs.push(cost);
            }
            costs
        }));
    }
    for h in handles {
        let costs = h.join().unwrap();
        assert_eq!(costs.len(), 5);
        assert!(costs.iter().all(|c| c.is_finite()));
    }
}

#[test]
fn malformed_lines_get_error_responses_not_disconnects() {
    let Some(ts) = TestServer::start() else { return };
    let stream = TcpStream::connect(&ts.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    for bad in ["not json", "{}", r#"{"op":"fly"}"#, r#"{"op":"align","query":[1,"x"]}"#] {
        writer.write_all(bad.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\":false"), "line {line:?} for input {bad:?}");
    }
    // connection still alive afterwards
    writer.write_all(b"{\"op\":\"ping\"}\n").unwrap();
    writer.flush().unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("pong"));
}

#[test]
fn wrong_qlen_is_protocol_error() {
    let Some(ts) = TestServer::start() else { return };
    let mut client = Client::connect(&ts.addr).unwrap();
    let err = client.align(&[0.0; 32], AlignOptions::default());
    assert!(err.is_err());
    // and the connection keeps working
    client.ping().unwrap();
}

#[test]
fn append_and_stream_search_roundtrip() {
    let Some(ts) = TestServer::start() else { return };
    let mut client = Client::connect(&ts.addr).unwrap();
    let mut rng = Xoshiro256::new(7);
    let stream_opts = SearchOptions { k: 3, stream: true, ..Default::default() };

    // streaming search before any append: a protocol error, not a crash
    let q = rng.normal_vec_f32(128);
    assert!(client.search(&q, stream_opts).is_err());
    client.ping().unwrap();

    // first append opens the session (auto shape: window 192 = 3*128/2)
    let a1 = client.append(&rng.normal_vec_f32(512), AppendOptions::default()).unwrap();
    assert_eq!(a1.appended, 512);
    assert_eq!(a1.stream_len, 2048 + 512);
    assert_eq!(a1.window, 192);
    assert_eq!(a1.stride, 1);
    assert_eq!(a1.candidates, (a1.stream_len - a1.window) + 1);
    // a mismatched shape is rejected; the session survives
    assert!(client
        .append(&[1.0, 2.0], AppendOptions { window: 64, stride: 1 })
        .is_err());

    // cold streaming search walks every candidate
    let s1 = client.search(&q, stream_opts).unwrap();
    assert_eq!(s1.windows, a1.candidates);
    assert_eq!(
        s1.pruned_kim + s1.pruned_keogh + s1.dp_abandoned + s1.skipped + s1.dp_full,
        s1.windows,
        "counters must partition the candidate space over the wire"
    );

    // same query, nothing appended: a pure delta — zero candidates, and
    // bit-identical hits served from the cache
    let s2 = client.search(&q, stream_opts).unwrap();
    assert_eq!(s2.windows, 0, "empty delta after no appends");
    assert_eq!(s1.hits.len(), s2.hits.len());
    for (a, b) in s1.hits.iter().zip(&s2.hits) {
        assert_eq!(a.start, b.start);
        assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "wire must be bit-exact");
    }

    // grow the stream; the next delta walks exactly the new candidates
    let a2 = client.append(&rng.normal_vec_f32(256), AppendOptions::default()).unwrap();
    assert_eq!(a2.stream_len, 2048 + 512 + 256);
    let s3 = client.search(&q, stream_opts).unwrap();
    assert_eq!(s3.windows, 256, "delta = one new candidate per appended sample");

    // metrics surface the streaming session
    let m = client.metrics().unwrap();
    assert_eq!(m.stream_appends, 2);
    assert_eq!(m.stream_samples, 512 + 256);
    assert_eq!(m.delta_searches, 3);
    assert_eq!(m.delta_scanned, a1.candidates + 256);
    assert!(m.delta_skipped > 0);
}
