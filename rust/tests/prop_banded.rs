//! Property tests for band-constrained (Sakoe-Chiba) search: every DP
//! kernel's banded path is bit-identical to the anchored banded oracle
//! (`dtw::sdtw_banded_anchored_into`), a band that covers the window is
//! bit-identical to the unconstrained search, and the banded cascade's
//! top-K is invariant across the serial, sharded, and streaming-delta
//! executors with partition-exact counters.  Via the in-repo property
//! harness.

use std::sync::Arc;

use sdtw_repro::dtw::{
    band_feasible, sdtw_banded, sdtw_banded_anchored_into, Dist, KernelKind, KernelSpec, Lane,
};
use sdtw_repro::search::{
    select_topk, CascadeOpts, Hit, LbKernelSpec, ReferenceIndex, SearchEngine, StreamingEngine,
};
use sdtw_repro::testutil::check;

/// Random-walk style series (levels drift — the family where envelopes
/// and bands both do real work).
fn walk(g: &mut sdtw_repro::testutil::GenCtx, lo: usize, hi: usize) -> Vec<f32> {
    let base = g.vec_f32(lo, hi);
    let mut level = 0f32;
    base.iter()
        .map(|&step| {
            level += step * 0.5;
            level
        })
        .collect()
}

/// Banded brute force: cost every candidate window with the anchored
/// banded oracle, then the shared greedy selection.
fn banded_brute_topk(
    query: &[f32],
    index: &ReferenceIndex,
    band: usize,
    k: usize,
    exclusion: usize,
) -> Vec<Hit> {
    let mut prev = Vec::new();
    let mut cur = Vec::new();
    let mut hits = Vec::new();
    for t in 0..index.candidates() {
        if let Some(m) = sdtw_banded_anchored_into(
            query,
            index.window_slice(t),
            band,
            f32::INFINITY,
            Dist::Sq,
            &mut prev,
            &mut cur,
        ) {
            let start = index.start(t);
            hits.push(Hit { start, end: start + m.end, cost: m.cost });
        }
    }
    select_topk(&hits, k, exclusion)
}

fn assert_bit_identical(label: &str, a: &[Hit], b: &[Hit]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: {} vs {} hits", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.start != y.start || x.end != y.end || x.cost.to_bits() != y.cost.to_bits() {
            return Err(format!("{label}: hit {i} differs: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_banded_kernels_bit_identical_to_anchored_oracle() {
    // every DpKernel::run_banded over ragged lanes == the anchored
    // oracle, cell for cell, including infeasible lanes (None) and the
    // early-abandon threshold
    check(801, 150, |g| {
        let n_lanes = g.usize_in(1, 9);
        let mut queries = Vec::with_capacity(n_lanes);
        let mut windows = Vec::with_capacity(n_lanes);
        for _ in 0..n_lanes {
            queries.push(g.vec_f32(1, 12));
            windows.push(walk(g, 1, 24));
        }
        let lanes: Vec<Lane<'_>> = queries
            .iter()
            .zip(&windows)
            .map(|(q, w)| Lane { query: q, window: w })
            .collect();
        let band = g.usize_in(0, 16); // 0 is a legal (degenerate) radius here
        let abandon_at = if g.usize_in(0, 1) == 0 { f32::INFINITY } else { 4.0 };

        // oracle per lane
        let mut prev = Vec::new();
        let mut cur = Vec::new();
        let want: Vec<_> = lanes
            .iter()
            .map(|l| {
                sdtw_banded_anchored_into(
                    l.query,
                    l.window,
                    band,
                    abandon_at,
                    Dist::Sq,
                    &mut prev,
                    &mut cur,
                )
            })
            .collect();

        let specs = [
            KernelSpec::SCALAR,
            KernelSpec { kind: KernelKind::Scan, width: g.usize_in(1, 8), lanes: 0 },
            KernelSpec { kind: KernelKind::Lanes, width: 0, lanes: g.usize_in(1, 6) },
        ];
        let mut got = Vec::new();
        for spec in specs {
            let mut kernel = spec.instantiate();
            kernel.run_banded(&lanes, band, abandon_at, Dist::Sq, &mut got);
            if got.len() != want.len() {
                return Err(format!("{}: {} results for {} lanes", kernel.name(), got.len(), want.len()));
            }
            for (i, (a, b)) in got.iter().zip(&want).enumerate() {
                let same = match (a, b) {
                    (None, None) => true,
                    (Some(x), Some(y)) => {
                        x.end == y.end && x.cost.to_bits() == y.cost.to_bits()
                    }
                    _ => false,
                };
                if !same {
                    return Err(format!(
                        "{} lane {i} (band {band}): {a:?} vs oracle {b:?}",
                        kernel.name()
                    ));
                }
                let feasible = band_feasible(lanes[i].query.len(), lanes[i].window.len(), band);
                if !feasible && a.is_some() {
                    return Err(format!(
                        "{} lane {i}: infeasible band {band} produced {a:?}",
                        kernel.name()
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_global_banded_oracle_is_min_over_anchored_starts() {
    // sdtw_banded over the whole reference == the best anchored banded
    // alignment over every start's tail — the identity that makes the
    // stride-1 banded search a faithful decomposition of the global scan
    check(802, 150, |g| {
        let q = g.vec_f32(1, 10);
        let r = walk(g, 1, 40);
        let band = g.usize_in(1, 12);
        let global = sdtw_banded(&q, &r, band, Dist::Sq);
        let mut prev = Vec::new();
        let mut cur = Vec::new();
        let mut best: Option<(f32, usize)> = None;
        for s in 0..r.len() {
            if let Some(m) = sdtw_banded_anchored_into(
                &q,
                &r[s..],
                band,
                f32::INFINITY,
                Dist::Sq,
                &mut prev,
                &mut cur,
            ) {
                // same tie policy as sdtw_banded: strict improvement in
                // the same start order keeps the earliest start on ties
                if best.map_or(true, |(c, _)| m.cost < c) {
                    best = Some((m.cost, s + m.end));
                }
            }
        }
        match best {
            None => {
                if global.cost.is_finite() {
                    return Err(format!("no anchored start but global {global:?}"));
                }
            }
            Some((cost, end)) => {
                if cost.to_bits() != global.cost.to_bits() || end != global.end {
                    return Err(format!(
                        "anchored min ({cost}, {end}) != global {global:?} (band {band})"
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_band_covering_window_is_bit_identical_to_unconstrained() {
    // band >= window resolves to the unconstrained search at the options
    // layer: hits AND stats must be identical, bit for bit
    check(803, 100, |g| {
        let r = Arc::new(walk(g, 40, 160));
        let m = g.usize_in(3, 10);
        let window = g.usize_in(m, (m + 10).min(r.len()));
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(0, window);
        let q = g.vec_f32(m, m);
        let engine =
            SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let base = engine
            .search_opts(&q, k, exclusion, CascadeOpts::default(), 1)
            .map_err(|e| e.to_string())?;
        for band in [window, window + 1, window + 977] {
            let opts = CascadeOpts::default().with_band(band);
            let got = engine
                .search_opts(&q, k, exclusion, opts, 1)
                .map_err(|e| e.to_string())?;
            if got != base {
                return Err(format!("band {band} (window {window}) diverged: {got:?}"));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_banded_cascade_topk_invariant_across_executors() {
    // the acceptance invariant: banded cascade top-K == banded brute
    // force, identically on the serial, sharded, and streaming-delta
    // paths, with partition-exact counters everywhere
    check(804, 80, |g| {
        let r = Arc::new(walk(g, 60, 200));
        let m = g.usize_in(3, 10);
        let window = g.usize_in(m, (m + 10).min(r.len()));
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(1, window);
        let band = g.usize_in(1, window.saturating_sub(1).max(1));
        let q = g.vec_f32(m, m);
        let engine =
            SearchEngine::new(r.clone(), window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let brute = banded_brute_topk(&q, engine.index(), band, k, exclusion);

        let variants = [
            CascadeOpts::default(),
            CascadeOpts::default().with_kernel(KernelSpec {
                kind: KernelKind::Lanes,
                width: 0,
                lanes: g.usize_in(1, 5),
            }),
            CascadeOpts::default().with_lb(LbKernelSpec::block(g.usize_in(1, 8))),
        ];
        for base in variants {
            let opts = base.with_band(band);
            let serial = engine
                .search_opts(&q, k, exclusion, opts, 1)
                .map_err(|e| e.to_string())?;
            assert_bit_identical("serial", &serial.hits, &brute)?;
            let s = serial.stats;
            if s.pruned_total() + s.dp_full != s.candidates {
                return Err(format!("serial counters don't partition: {s:?}"));
            }

            let shards = g.usize_in(2, 5);
            let sharded = engine
                .search_opts(&q, k, exclusion, opts, shards)
                .map_err(|e| e.to_string())?;
            assert_bit_identical("sharded", &sharded.hits, &brute)?;
            let s = sharded.stats;
            if s.pruned_total() + s.dp_full != s.candidates {
                return Err(format!("sharded counters don't partition: {s:?}"));
            }
        }

        // streaming: warm up on a prefix, append the rest in chunks, and
        // delta-search with the band — hits must match the rebuilt brute
        let opts = CascadeOpts::default().with_band(band);
        let warm = g.usize_in(window, r.len());
        let mut stream =
            StreamingEngine::new(&r[..warm], window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        // a mid-stream banded search populates the delta cache so the
        // final pass exercises the watermark path, not a cold rebuild
        stream
            .search_delta(&q, k, exclusion, opts)
            .map_err(|e| e.to_string())?;
        let mut at = warm;
        while at < r.len() {
            let end = (at + g.usize_in(1, 40)).min(r.len());
            stream.append(&r[at..end]);
            at = end;
        }
        let d = stream
            .search_delta(&q, k, exclusion, opts)
            .map_err(|e| e.to_string())?;
        assert_bit_identical("streaming", &d.outcome.hits, &brute)?;
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_infeasible_band_prunes_everything() {
    // query longer than window + band: no candidate can align inside the
    // band, the whole range lands in `pruned_band`, and no stage runs
    check(805, 80, |g| {
        let r = Arc::new(walk(g, 40, 120));
        let window = g.usize_in(2, 12.min(r.len()));
        // band must stay < window or the options layer resolves it to
        // the unconstrained search
        let band = g.usize_in(1, (window - 1).min(4));
        let m = window + band + g.usize_in(1, 6); // strictly infeasible
        let q = g.vec_f32(m, m);
        if band_feasible(q.len(), window, band) {
            return Err("generator produced a feasible shape".into());
        }
        let engine =
            SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let opts = CascadeOpts::default().with_band(band);
        let out = engine
            .search_opts(&q, 3, 1, opts, 1)
            .map_err(|e| e.to_string())?;
        if !out.hits.is_empty() {
            return Err(format!("infeasible band produced hits: {:?}", out.hits));
        }
        let s = out.stats;
        if s.pruned_band != s.candidates || s.dp_full != 0 || s.survivor_batches != 0 {
            return Err(format!("infeasible band mis-accounted: {s:?}"));
        }
        Ok(())
    })
    .unwrap();
}
