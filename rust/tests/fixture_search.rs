//! Cross-language parity: the Rust lower bounds and windowed sDTW costs
//! must match the Python reference (`python/compile/kernels/ref.py`) on
//! the shared fixture `tests/fixtures/search_lb.json`, which
//! `python/tests/test_search.py` validates from the other side.
//!
//! The fixture stores float32-representable inputs plus float64 expected
//! values, so both sides decode the exact same numbers; comparisons use
//! a small relative tolerance for the f32-vs-f64 accumulation gap.

use std::sync::Arc;

use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::search::envelope::sliding_min_max;
use sdtw_repro::search::lower_bounds::{lb_keogh, lb_kim};
use sdtw_repro::search::{select_topk, Hit, SearchEngine};
use sdtw_repro::util::json::Json;

fn fixture() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/search_lb.json");
    let text = std::fs::read_to_string(path).expect("fixture present");
    Json::parse(&text).expect("fixture is valid json")
}

fn f32s(v: &Json, key: &str) -> Vec<f32> {
    v.get(key)
        .and_then(Json::as_arr)
        .expect(key)
        .iter()
        .map(|x| x.as_f64().expect("numeric") as f32)
        .collect()
}

fn f64s(v: &Json, key: &str) -> Vec<f64> {
    v.get(key)
        .and_then(Json::as_arr)
        .expect(key)
        .iter()
        .map(|x| x.as_f64().expect("numeric"))
        .collect()
}

fn close(a: f32, b: f64, what: &str, s: usize) {
    let tol = 2e-3 * b.abs().max(1.0);
    assert!(
        (a as f64 - b).abs() <= tol,
        "{what}[{s}]: rust {a} vs python {b}"
    );
}

#[test]
fn bounds_and_costs_match_python_reference() {
    let v = fixture();
    let reference = f32s(&v, "reference");
    let query = f32s(&v, "query");
    let window = v.get("window").and_then(Json::as_i64).expect("window") as usize;
    let want_kim = f64s(&v, "lb_kim");
    let want_keogh = f64s(&v, "lb_keogh");
    let want_costs = f64s(&v, "costs");

    let (lo, hi) = sliding_min_max(&reference, window);
    assert_eq!(lo.len(), want_kim.len(), "candidate count");

    for s in 0..lo.len() {
        let kim = lb_kim(&query, lo[s], hi[s], Dist::Sq);
        let keogh = lb_keogh(&query, lo[s], hi[s], Dist::Sq, f32::INFINITY);
        let cost = sdtw(&query, &reference[s..s + window], Dist::Sq).cost;
        close(kim, want_kim[s], "lb_kim", s);
        close(keogh, want_keogh[s], "lb_keogh", s);
        close(cost, want_costs[s], "cost", s);
        // the admissibility chain, on the Rust side of the fixture
        assert!(kim <= keogh + 1e-4, "kim {kim} > keogh {keogh} at {s}");
        assert!(
            keogh <= cost + 1e-3 * cost.max(1.0),
            "keogh {keogh} > cost {cost} at {s}"
        );
    }
}

#[test]
fn cascade_on_fixture_matches_brute_force() {
    let v = fixture();
    let reference = Arc::new(f32s(&v, "reference"));
    let query = f32s(&v, "query");
    let window = v.get("window").and_then(Json::as_i64).expect("window") as usize;

    let engine = SearchEngine::new(reference.clone(), window, 1, Dist::Sq).unwrap();
    let (k, exclusion) = (3, window / 2);
    let brute: Vec<Hit> = (0..engine.index().candidates())
        .map(|t| {
            let m = sdtw(&query, engine.index().window_slice(t), Dist::Sq);
            Hit { start: t, end: t + m.end, cost: m.cost }
        })
        .collect();
    let brute = select_topk(&brute, k, exclusion);
    let cascade = engine.search(&query, k, exclusion).unwrap();
    assert_eq!(cascade.hits, brute);
    // the fixture plants a copy at 100: the best site must sit on it
    assert!(
        cascade.hits[0].start >= 100 - window + query.len() && cascade.hits[0].start <= 100,
        "best hit start {} not on the planted copy",
        cascade.hits[0].start
    );
}
