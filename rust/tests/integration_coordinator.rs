//! Integration: the serving coordinator over real artifacts — batching
//! under concurrency, request↔response mapping, option routing, error
//! paths, graceful shutdown.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use sdtw_repro::coordinator::{AlignOptions, SdtwService, ServiceOptions};
use sdtw_repro::dtw::{self, Dist};
use sdtw_repro::normalize;
use sdtw_repro::util::rng::Xoshiro256;

const VARIANT: &str = "pipeline_b8_m128_n2048_w16";

fn service(workers: usize, deadline_ms: u64) -> Option<(SdtwService, Vec<f32>)> {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return None;
    }
    let mut rng = Xoshiro256::new(77);
    let reference = rng.normal_vec_f32(2048);
    let svc = SdtwService::start(
        ServiceOptions {
            variant: VARIANT.into(),
            workers,
            batch_deadline: Duration::from_millis(deadline_ms),
            ..Default::default()
        },
        reference.clone(),
    )
    .unwrap();
    Some((svc, reference))
}

#[test]
fn responses_match_oracle_and_request_mapping() {
    let Some((svc, reference)) = service(1, 3) else { return };
    let mut rng = Xoshiro256::new(8);
    let queries: Vec<Vec<f32>> = (0..13) // crosses batch boundaries (B=8)
        .map(|_| {
            (0..128)
                .map(|_| rng.normal_ms(2.0, 4.0) as f32)
                .collect::<Vec<f32>>()
        })
        .collect();
    let responses = svc.align_many(&queries, AlignOptions::default()).unwrap();
    assert_eq!(responses.len(), 13);

    let rn = normalize::znormed(&reference);
    for (q, r) in queries.iter().zip(&responses) {
        let want = dtw::sdtw(&normalize::znormed(q), &rn, Dist::Sq);
        let rel = (r.cost - want.cost).abs() / want.cost.max(1.0);
        assert!(rel < 1e-3, "{} vs {}", r.cost, want.cost);
        assert_eq!(r.end, want.end);
        assert!(r.latency_ms > 0.0);
        assert_eq!(r.variant, VARIANT);
    }
    let m = svc.metrics();
    assert_eq!(m.responses, 13);
    assert!(m.batches >= 2, "13 requests must span >= 2 batches of 8");
    assert_eq!(m.errors, 0);
}

#[test]
fn concurrent_clients_are_batched_together() {
    let Some((svc, _)) = service(1, 8) else { return };
    let svc = Arc::new(svc);
    let mut handles = Vec::new();
    for t in 0..16 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::stream(9, t);
            let q = rng.normal_vec_f32(128);
            svc.align_blocking(q, AlignOptions::default()).unwrap()
        }));
    }
    let ids: Vec<u64> = handles
        .into_iter()
        .map(|h| h.join().unwrap().id)
        .collect();
    // all distinct ids answered
    let mut sorted = ids.clone();
    sorted.sort_unstable();
    sorted.dedup();
    assert_eq!(sorted.len(), 16);
    let m = svc.metrics();
    assert_eq!(m.responses, 16);
    // cross-client batching actually happened (16 requests, B=8, so at
    // most 16 batches; with a deadline it should be well under that)
    assert!(m.batches < 16, "batches {} show no dynamic batching", m.batches);
    assert!(m.real_rows as f64 / m.batches as f64 > 1.0);
}

#[test]
fn option_routing_reaches_special_variants() {
    let Some((svc, _)) = service(1, 2) else { return };
    let mut rng = Xoshiro256::new(10);
    let q = rng.normal_vec_f32(128);

    let half = svc
        .align_blocking(q.clone(), AlignOptions { half: true, ..Default::default() })
        .unwrap();
    assert!(half.variant.contains("bf16"), "{}", half.variant);

    let pruned = svc
        .align_blocking(q.clone(), AlignOptions { pruned: true, ..Default::default() })
        .unwrap();
    assert!(pruned.variant.contains("pruned"), "{}", pruned.variant);

    let quant = svc
        .align_blocking(q.clone(), AlignOptions { quantized: true, ..Default::default() })
        .unwrap();
    assert!(quant.variant.contains("quant"), "{}", quant.variant);

    // exact and half agree loosely; exact and quant agree loosely
    let exact = svc.align_blocking(q, AlignOptions::default()).unwrap();
    assert!((exact.cost - half.cost).abs() / exact.cost.max(1.0) < 0.1);
    assert!((exact.cost - quant.cost).abs() / exact.cost.max(1.0) < 0.1);
}

#[test]
fn wrong_query_length_rejected_synchronously() {
    let Some((svc, _)) = service(1, 2) else { return };
    let err = svc.submit(vec![0.0; 64], AlignOptions::default());
    assert!(err.is_err(), "qlen 64 has no variant at reflen 2048");
}

#[test]
fn shutdown_drains_inflight() {
    let Some((mut svc, _)) = service(1, 50) else { return };
    let mut rng = Xoshiro256::new(11);
    // submit a partial batch, then shut down before the deadline expires:
    // the dispatcher must flush it, not drop it
    let rx1 = svc.submit(rng.normal_vec_f32(128), AlignOptions::default()).unwrap();
    let rx2 = svc.submit(rng.normal_vec_f32(128), AlignOptions::default()).unwrap();
    svc.shutdown();
    assert!(rx1.recv().unwrap().is_ok());
    assert!(rx2.recv().unwrap().is_ok());
}

#[test]
fn service_rejects_bad_reference_length() {
    if !Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let r = SdtwService::start(
        ServiceOptions { variant: VARIANT.into(), ..Default::default() },
        vec![0.0; 999],
    );
    assert!(r.is_err());
}

#[test]
fn service_rejects_unknown_variant() {
    if !Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let r = SdtwService::start(
        ServiceOptions { variant: "nope".into(), ..Default::default() },
        vec![0.0; 2048],
    );
    assert!(r.is_err());
}
