//! Property tests for the search subsystem: lower-bound admissibility
//! (the cascade's correctness precondition) and bit-identical agreement
//! between the cascade and brute-force `dtw::subsequence` top-K (the
//! losslessness guarantee).  Via the in-repo property harness.

use std::sync::Arc;

use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::search::lower_bounds::{lb_keogh, lb_kim};
use sdtw_repro::search::{
    select_topk, CascadeOpts, Hit, ReferenceIndex, SearchEngine,
};
use sdtw_repro::testutil::check;
use sdtw_repro::util::rng::Xoshiro256;

/// Random-walk style series: the workload family where envelope bounds
/// do real work (levels drift).
fn walk(g: &mut sdtw_repro::testutil::GenCtx, lo: usize, hi: usize) -> Vec<f32> {
    let base = g.vec_f32(lo, hi);
    let mut level = 0f32;
    base.iter()
        .map(|&step| {
            level += step * 0.5;
            level
        })
        .collect()
}

/// Brute force from `dtw::subsequence`: cost every candidate window with
/// the oracle, then the shared greedy selection.
fn brute_topk(
    query: &[f32],
    index: &ReferenceIndex,
    k: usize,
    exclusion: usize,
) -> Vec<Hit> {
    let hits: Vec<Hit> = (0..index.candidates())
        .map(|t| {
            let m = sdtw(query, index.window_slice(t), Dist::Sq);
            let start = index.start(t);
            Hit { start, end: start + m.end, cost: m.cost }
        })
        .collect();
    select_topk(&hits, k, exclusion)
}

fn assert_bit_identical(label: &str, a: &[Hit], b: &[Hit]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: {} vs {} hits", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.start != y.start || x.end != y.end || x.cost.to_bits() != y.cost.to_bits() {
            return Err(format!("{label}: hit {i} differs: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_lb_chain_kim_le_keogh_le_cost() {
    // the satellite invariant: LB_Kim <= LB_Keogh <= true windowed sDTW
    check(300, 300, |g| {
        let q = g.vec_f32(1, 16);
        let w = walk(g, 1, 40);
        let lo = w.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for dist in [Dist::Sq, Dist::Abs] {
            let kim = lb_kim(&q, lo, hi, dist);
            let keogh = lb_keogh(&q, lo, hi, dist, f32::INFINITY);
            let cost = sdtw(&q, &w, dist).cost;
            let tol = 1e-3 * cost.abs().max(1.0);
            if kim > keogh + tol {
                return Err(format!("kim {kim} > keogh {keogh}"));
            }
            if keogh > cost + tol {
                return Err(format!("keogh {keogh} > cost {cost} ({dist:?})"));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_cascade_topk_bit_identical_to_brute() {
    // the acceptance invariant, over random shapes, strides, K, exclusion
    check(301, 120, |g| {
        let r = Arc::new(walk(g, 40, 220));
        let m = g.usize_in(3, 14);
        let window = g.usize_in(m, (m + 12).min(r.len()));
        let stride = g.usize_in(1, 3);
        let k = g.usize_in(1, 5);
        let exclusion = g.usize_in(0, window);
        let q = g.vec_f32(m, m);

        let engine = SearchEngine::new(r.clone(), window, stride, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let brute = brute_topk(&q, engine.index(), k, exclusion);
        let cascade = engine
            .search(&q, k, exclusion)
            .map_err(|e| e.to_string())?;
        assert_bit_identical("cascade", &cascade.hits, &brute)?;

        let stats = cascade.stats;
        if stats.pruned_total() + stats.dp_full != stats.candidates {
            return Err(format!("counters don't partition candidates: {stats:?}"));
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_every_stage_combination_is_lossless() {
    check(302, 60, |g| {
        let r = Arc::new(walk(g, 60, 160));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(r.len()));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let q = g.vec_f32(m, m);
        let engine =
            SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let brute = brute_topk(&q, engine.index(), k, exclusion);
        for kim in [false, true] {
            for keogh in [false, true] {
                for abandon in [false, true] {
                    let opts = CascadeOpts { kim, keogh, abandon, ..Default::default() };
                    let got = engine
                        .search_opts(&q, k, exclusion, opts, 1)
                        .map_err(|e| e.to_string())?;
                    assert_bit_identical(
                        &format!("opts {opts:?}"),
                        &got.hits,
                        &brute,
                    )?;
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_search_is_lossless() {
    check(303, 60, |g| {
        let r = Arc::new(walk(g, 60, 200));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 10).min(r.len()));
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(1, window);
        let shards = g.usize_in(2, 6);
        let q = g.vec_f32(m, m);
        let engine =
            SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let brute = brute_topk(&q, engine.index(), k, exclusion);
        let sharded = engine
            .search_opts(&q, k, exclusion, CascadeOpts::default(), shards)
            .map_err(|e| e.to_string())?;
        assert_bit_identical("sharded", &sharded.hits, &brute)
    })
    .unwrap();
}

#[test]
fn cascade_prunes_majority_on_planted_walk_workload() {
    // the bench acceptance criterion as a regression test: >= 50% of
    // candidate windows pruned on a planted random-walk workload
    let mut rng = Xoshiro256::new(7);
    let n = 8192;
    let m = 64;
    let window = 96;
    let mut level = 0f64;
    let mut reference: Vec<f32> = (0..n)
        .map(|_| {
            level += rng.normal() * 0.4;
            level as f32
        })
        .collect();
    let query: Vec<f32> = rng.normal_vec_f32(m);
    for at in [1000usize, 3000, 5000, 7000] {
        let stretch = rng.uniform(0.85, 1.2);
        sdtw_repro::datagen::embed_query(&mut reference, &query, at, stretch, 0.05, &mut rng);
    }
    let rn = Arc::new(sdtw_repro::normalize::znormed(&reference));
    let qn = sdtw_repro::normalize::znormed(&query);
    let engine = SearchEngine::new(rn, window, 1, Dist::Sq).unwrap();

    let out = engine.search(&qn, 4, window / 2).unwrap();
    // all four planted sites recovered, in some order
    assert_eq!(out.hits.len(), 4);
    for h in &out.hits {
        let near = [1000usize, 3000, 5000, 7000]
            .iter()
            .any(|&at| h.end + m >= at && h.end <= at + 2 * m);
        assert!(near, "hit end {} not near a planted site", h.end);
    }
    // the acceptance threshold, with real margin
    assert!(
        out.stats.prune_fraction() >= 0.5,
        "cascade pruned only {:.1}% of {} windows ({:?})",
        out.stats.prune_fraction() * 100.0,
        out.stats.candidates,
        out.stats
    );
    // and it is still exact
    let brute = brute_topk(&qn, engine.index(), 4, window / 2);
    assert_bit_identical("planted", &out.hits, &brute).unwrap();
}
