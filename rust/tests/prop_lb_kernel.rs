//! Property tests for the batched lower-bound prefilter layer
//! (`search::lb_kernel`): the block kernel must be **bit-identical** to
//! the scalar kernel — and both to the `lower_bounds` oracles — on every
//! lane, for any ragged block size, both distance measures, and any τ
//! (including the early-abandon flags); and the cascade must return
//! bit-identical top-K hits with partition-exact counters no matter
//! which LB kernel drives its Kim/Keogh stages, on the serial, sharded,
//! and streaming paths alike.  This is the referee the prefilter
//! refactor stands on: if these pass, re-pointing the cascade through
//! the LB kernel layer cannot have changed any search result anywhere.

use std::sync::Arc;

use sdtw_repro::dtw::Dist;
use sdtw_repro::search::lower_bounds::{lb_keogh, lb_keogh_verdict, lb_kim};
use sdtw_repro::search::{
    CascadeOpts, CascadeStats, Hit, LbKernel, LbKernelSpec, SearchEngine, StreamingEngine,
};
use sdtw_repro::testutil::{check, GenCtx};

/// The LB-kernel zoo a property run exercises: the scalar referee plus
/// block sizes from degenerate 1 through ragged mid-sizes to the
/// 1..=64 range the issue calls out (64 = the auto default).
fn specs(g: &mut GenCtx) -> Vec<LbKernelSpec> {
    vec![
        LbKernelSpec::SCALAR,
        LbKernelSpec::block(1),
        LbKernelSpec::block(g.usize_in(2, 7)),
        LbKernelSpec::block(g.usize_in(8, 63)),
        LbKernelSpec::block(64),
    ]
}

/// Random SoA envelope block: `lo[k] <= hi[k]` for every lane.
fn envelope_block(g: &mut GenCtx, lanes: usize) -> (Vec<f32>, Vec<f32>) {
    let lo = g.vec_f32(lanes, lanes);
    let hi: Vec<f32> = lo.iter().map(|&l| l + g.f32_in(0.0, 2.5)).collect();
    (lo, hi)
}

#[test]
fn prop_block_kim_bit_identical_to_scalar_oracle() {
    check(601, 150, |g| {
        let q = g.vec_f32(1, 14);
        let lanes = g.usize_in(1, 80);
        let (lo, hi) = envelope_block(g, lanes);
        let dist = if g.usize_in(0, 1) == 0 { Dist::Sq } else { Dist::Abs };
        for spec in specs(g) {
            let mut kernel = spec.instantiate();
            let mut out = Vec::new();
            kernel.kim(&q, &lo, &hi, dist, &mut out);
            if out.len() != lanes {
                return Err(format!("{spec:?}: {} results for {lanes} lanes", out.len()));
            }
            for (k, &got) in out.iter().enumerate() {
                let want = lb_kim(&q, lo[k], hi[k], dist);
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "{spec:?} lane {k}: kim {got} vs oracle {want} (not bit-identical)"
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_block_keogh_bit_identical_with_abandon_flags() {
    check(602, 150, |g| {
        let q = g.vec_f32(1, 12);
        let lanes = g.usize_in(1, 80);
        let (lo, hi) = envelope_block(g, lanes);
        let dist = if g.usize_in(0, 1) == 0 { Dist::Sq } else { Dist::Abs };
        // τ spanning "prunes everything" to "prunes nothing" (+∞)
        let tau = if g.usize_in(0, 4) == 0 { f32::INFINITY } else { g.f32_in(0.0, 12.0) };
        for spec in specs(g) {
            let mut kernel = spec.instantiate();
            let mut out = Vec::new();
            kernel.keogh(&q, &lo, &hi, dist, tau, &mut out);
            if out.len() != lanes {
                return Err(format!("{spec:?}: {} verdicts for {lanes} lanes", out.len()));
            }
            for (k, v) in out.iter().enumerate() {
                let want = lb_keogh_verdict(&q, lo[k], hi[k], dist, tau);
                if v.bound.to_bits() != want.bound.to_bits() {
                    return Err(format!(
                        "{spec:?} lane {k} τ={tau}: bound {} vs {} (not bit-identical)",
                        v.bound, want.bound
                    ));
                }
                if v.pruned != want.pruned || v.abandoned != want.abandoned {
                    return Err(format!(
                        "{spec:?} lane {k} τ={tau}: flags ({}, {}) vs ({}, {})",
                        v.pruned, v.abandoned, want.pruned, want.abandoned
                    ));
                }
                // the legacy entry point and the verdict agree on value
                let legacy = lb_keogh(&q, lo[k], hi[k], dist, tau);
                if legacy.to_bits() != want.bound.to_bits() {
                    return Err(format!(
                        "lane {k}: lb_keogh {legacy} diverged from verdict {}",
                        want.bound
                    ));
                }
                // flag semantics: abandoned ⇒ pruned, and an abandoned
                // bound is still admissible (≤ the full bound)
                if v.abandoned && !v.pruned {
                    return Err(format!("{spec:?} lane {k}: abandoned but not pruned"));
                }
                let full = lb_keogh(&q, lo[k], hi[k], dist, f32::INFINITY);
                if v.bound > full {
                    return Err(format!(
                        "{spec:?} lane {k}: partial bound {} above full {full}",
                        v.bound
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

fn hits_identical(a: &[Hit], b: &[Hit]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("pick counts differ: {} vs {}", a.len(), b.len()));
    }
    for (x, y) in a.iter().zip(b) {
        if x.start != y.start || x.end != y.end || x.cost.to_bits() != y.cost.to_bits() {
            return Err(format!("hit {x:?} vs {y:?} (not bit-identical)"));
        }
    }
    Ok(())
}

fn partition_ok(s: &CascadeStats) -> Result<(), String> {
    if s.pruned_total() + s.dp_full != s.candidates {
        return Err(format!("counters do not partition the candidates: {s:?}"));
    }
    if s.lb_abandons > s.pruned_keogh {
        return Err(format!("lb_abandons exceeds pruned_keogh: {s:?}"));
    }
    if s.lb_evals < s.lb_blocks {
        return Err(format!("blocks with no evaluations: {s:?}"));
    }
    Ok(())
}

#[test]
fn prop_cascade_topk_invariant_under_lb_kernel_choice() {
    // serial path: brute force == scalar LB == block LB at every size,
    // composed with the lane-batched DP kernel for good measure
    check(603, 50, |g| {
        let r = Arc::new(g.vec_f32(60, 160));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(r.len()));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let brute = engine
            .search_opts(&q, k, exclusion, CascadeOpts::BRUTE, 1)
            .map_err(|e| e.to_string())?;
        for spec in specs(g) {
            let opts = CascadeOpts::default()
                .with_lb(spec)
                .with_kernel(if g.usize_in(0, 1) == 0 {
                    sdtw_repro::dtw::KernelSpec::SCALAR
                } else {
                    sdtw_repro::dtw::KernelSpec::lanes(g.usize_in(1, 8))
                });
            let got = engine
                .search_opts(&q, k, exclusion, opts, 1)
                .map_err(|e| e.to_string())?;
            hits_identical(&got.hits, &brute.hits).map_err(|e| format!("{spec:?}: {e}"))?;
            partition_ok(&got.stats).map_err(|e| format!("{spec:?}: {e}"))?;
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_cascade_invariant_under_lb_kernel_choice() {
    check(604, 30, |g| {
        let r = Arc::new(g.vec_f32(120, 300));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(r.len()));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let serial = engine
            .search_opts(&q, k, exclusion, CascadeOpts::default(), 1)
            .map_err(|e| e.to_string())?;
        for spec in specs(g) {
            let opts = CascadeOpts::default().with_lb(spec);
            let shards = g.usize_in(2, 6);
            let threads = g.usize_in(1, 3);
            let out = engine
                .search_sharded(&q, k, exclusion, opts, shards, threads)
                .map_err(|e| e.to_string())?;
            hits_identical(&out.hits, &serial.hits).map_err(|e| format!("{spec:?}: {e}"))?;
            partition_ok(&out.stats).map_err(|e| format!("{spec:?}: {e}"))?;
            // per-shard counters partition each shard's range too
            for sh in &out.shards {
                if sh.stats.candidates != sh.range.len() as u64 {
                    return Err(format!("{spec:?} shard {}: range mismatch", sh.shard));
                }
                partition_ok(&sh.stats)
                    .map_err(|e| format!("{spec:?} shard {}: {e}", sh.shard))?;
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_streaming_delta_invariant_under_lb_kernel_choice() {
    // streaming path: delta searches with the block LB kernel stay
    // bit-identical to a full batch rebuild at every append step
    check(605, 25, |g| {
        let x = g.vec_f32(150, 300);
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(60));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let q = g.vec_f32(m, m);
        for spec in specs(g) {
            let opts = CascadeOpts::default().with_lb(spec);
            let warm = g.usize_in(window, 80.max(window));
            let mut se =
                StreamingEngine::new(&x[..warm], window, 1, Dist::Sq).map_err(|e| e.to_string())?;
            let mut at = warm;
            while at < x.len() {
                let end = (at + g.usize_in(20, 80)).min(x.len());
                se.append(&x[at..end]);
                at = end;
                let d = se
                    .search_delta(&q, k, exclusion, opts)
                    .map_err(|e| e.to_string())?;
                let batch = SearchEngine::new(Arc::new(x[..at].to_vec()), window, 1, Dist::Sq)
                    .map_err(|e| e.to_string())?
                    .search_opts(&q, k, exclusion, opts, 1)
                    .map_err(|e| e.to_string())?;
                hits_identical(&d.outcome.hits, &batch.hits)
                    .map_err(|e| format!("{spec:?} at {at}: {e}"))?;
                partition_ok(&d.outcome.stats).map_err(|e| format!("{spec:?} at {at}: {e}"))?;
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_k_zero_and_occupancy_bounds_hold() {
    // counter hygiene at the edges: k = 0 accounts everything as
    // skipped with zero LB work; occupancy never exceeds the block size
    check(606, 30, |g| {
        let r = Arc::new(g.vec_f32(60, 140));
        let window = g.usize_in(4, 16.min(r.len()));
        let q = g.vec_f32(4, 10);
        let engine = SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let block = g.usize_in(1, 64);
        let opts = CascadeOpts::default().with_lb(LbKernelSpec::block(block));
        let got = engine
            .search_opts(&q, 0, window / 2 + 1, opts, 1)
            .map_err(|e| e.to_string())?;
        let s = got.stats;
        if !got.hits.is_empty() || s.skipped != s.candidates || s.lb_blocks != 0 || s.lb_evals != 0
        {
            return Err(format!("k=0 did LB work or returned hits: {s:?}"));
        }
        let live = engine
            .search_opts(&q, 2, window / 2 + 1, opts, 1)
            .map_err(|e| e.to_string())?;
        let s = live.stats;
        partition_ok(&s)?;
        if s.lb_blocks > 0 && s.mean_lb_block_occupancy() > block as f64 + 1e-9 {
            return Err(format!(
                "occupancy {} exceeds block size {block}: {s:?}",
                s.mean_lb_block_occupancy()
            ));
        }
        Ok(())
    })
    .unwrap();
}
