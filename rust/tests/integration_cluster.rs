//! Integration: multi-node sharded search over real sockets.  Two
//! worker nodes (search-only services behind the blocking and reactor
//! front ends) receive index segments from a coordinator, which fans
//! every search out as `search.shard` verbs, relays τ-tightenings
//! between the nodes mid-search, and steals shard chunks on skew.
//! The contract under test everywhere: cluster hits are bit-identical
//! to the single-process serial engine, and the merged stage counters
//! partition the candidate space exactly.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use sdtw_repro::coordinator::{
    AppendOptions, SdtwService, SearchOptions, ServiceOptions,
};
use sdtw_repro::dtw::Dist;
use sdtw_repro::search::cluster::{run_shard, LocalBackend, RemoteTau};
use sdtw_repro::search::topk::prune_heap_cap;
use sdtw_repro::search::{CascadeOpts, Hit, StreamingEngine};
use sdtw_repro::server::{Client, Reactor, ReactorOptions, Server};
use sdtw_repro::util::rng::Xoshiro256;

fn search_only(reference: Vec<f32>) -> Arc<SdtwService> {
    Arc::new(
        SdtwService::start(
            ServiceOptions { search_only: true, ..Default::default() },
            reference,
        )
        .unwrap(),
    )
}

/// A worker node's own startup reference is irrelevant to cluster
/// traffic — everything it searches arrives via `segment.put`.
fn worker_service() -> Arc<SdtwService> {
    let mut rng = Xoshiro256::new(1);
    search_only(rng.normal_vec_f32(64))
}

struct TestServer {
    addr: String,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn blocking(service: Arc<SdtwService>) -> TestServer {
        let s = Server::bind(service, "127.0.0.1:0").unwrap();
        let addr = s.local_addr().unwrap().to_string();
        let stop = s.stop_flag();
        TestServer { addr, stop, join: Some(std::thread::spawn(move || s.serve())) }
    }

    fn reactor(service: Arc<SdtwService>) -> TestServer {
        let r = Reactor::bind(service, "127.0.0.1:0", ReactorOptions::default()).unwrap();
        let addr = r.local_addr().unwrap().to_string();
        let stop = r.stop_flag();
        TestServer { addr, stop, join: Some(std::thread::spawn(move || r.serve())) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// A coordinator service attached to the given worker addresses.
fn coordinator(reference: Vec<f32>, addrs: &[String]) -> SdtwService {
    let mut svc = SdtwService::start(
        ServiceOptions { search_only: true, ..Default::default() },
        reference,
    )
    .unwrap();
    svc.attach_cluster(addrs).unwrap();
    svc
}

/// The (window, stride) a coordinator over `reflen` samples resolves
/// for its cluster index — what a serial comparison search must pin.
fn cluster_shape(reflen: usize) -> (usize, usize) {
    let r = SearchOptions::default()
        .resolve(SdtwService::SEARCH_ONLY_QLEN, reflen)
        .unwrap();
    (r.window, r.stride)
}

fn assert_hits_bit_identical(cluster: &[Hit], serial: &[Hit], ctx: &str) {
    assert_eq!(cluster.len(), serial.len(), "{ctx}: hit count");
    for (a, b) in cluster.iter().zip(serial) {
        assert_eq!(
            (a.start, a.end, a.cost.to_bits()),
            (b.start, b.end, b.cost.to_bits()),
            "{ctx}: cluster hits must be bit-identical to serial"
        );
    }
}

#[test]
fn two_node_cluster_hits_are_bit_identical_to_serial_and_partition_exact() {
    let w1 = TestServer::blocking(worker_service());
    let w2 = TestServer::blocking(worker_service());
    let mut rng = Xoshiro256::new(40);
    let reference = rng.normal_vec_f32(512);
    let coord = coordinator(reference.clone(), &[w1.addr.clone(), w2.addr.clone()]);
    let serial = search_only(reference.clone());
    let (window, stride) = cluster_shape(reference.len());
    let total = ((reference.len() - window) / stride + 1) as u64;

    let mut searches = 0u64;
    for (seed, k, exclusion, band) in
        [(7u64, 1usize, 4usize, 0usize), (8, 3, 8, 0), (9, 2, 16, 40), (10, 5, 2, 0)]
    {
        let mut qrng = Xoshiro256::new(seed);
        let q = qrng.normal_vec_f32(32);
        let opts = SearchOptions { k, exclusion, band, ..Default::default() };
        let serial_resp = serial
            .search_blocking(q.clone(), SearchOptions { window, stride, ..opts })
            .unwrap();
        let resp = coord.search_blocking(q, opts).unwrap();
        searches += 1;

        let ctx = format!("seed={seed} k={k} exclusion={exclusion} band={band}");
        assert_hits_bit_identical(&resp.hits, &serial_resp.hits, &ctx);
        assert_eq!(resp.stats.candidates, total, "{ctx}: every candidate accounted");
        assert_eq!(
            resp.stats.pruned_total() + resp.stats.dp_full,
            resp.stats.candidates,
            "{ctx}: stage counters partition the candidate space"
        );
        // 2 nodes × 4 chunks each, whoever ends up executing them
        assert_eq!(resp.shards, 8, "{ctx}");
    }

    let m = coord.metrics();
    assert_eq!(m.cluster_nodes, 2);
    assert_eq!(m.searches, searches);
    assert_eq!(m.search_shards, 8 * searches);
    // k=1 gives a heap cap of 1: the first completed DP anywhere
    // publishes a finite τ, whose relay to the other node is observable
    assert!(
        m.tau_broadcasts >= 1,
        "a 2-node search must broadcast at least one τ-tightening, got {}",
        m.tau_broadcasts
    );
}

#[test]
fn cluster_search_serves_over_the_wire_with_cluster_counters() {
    let w1 = TestServer::blocking(worker_service());
    let w2 = TestServer::blocking(worker_service());
    let mut rng = Xoshiro256::new(50);
    let reference = rng.normal_vec_f32(480);
    let coord_svc =
        Arc::new(coordinator(reference.clone(), &[w1.addr.clone(), w2.addr.clone()]));
    let coord = TestServer::blocking(coord_svc);
    let serial = search_only(reference.clone());
    let (window, stride) = cluster_shape(reference.len());

    let q = rng.normal_vec_f32(48);
    let opts = SearchOptions { k: 2, exclusion: 6, ..Default::default() };
    let serial_resp = serial
        .search_blocking(q.clone(), SearchOptions { window, stride, ..opts })
        .unwrap();

    let mut client = Client::connect(&coord.addr).unwrap();
    let s = client.search(&q, opts).unwrap();
    assert_hits_bit_identical(&s.hits, &serial_resp.hits, "over the wire");
    assert_eq!(s.shards, 8, "per-node chunks surface as the response's shard count");
    assert_eq!(
        s.windows,
        ((reference.len() - window) / stride + 1) as u64,
        "candidate accounting crosses the wire"
    );

    // the new MetricsFields counters cross the wire too
    let m = client.metrics().unwrap();
    assert_eq!(m.cluster_nodes, 2);
    assert!(m.tau_broadcasts >= 1, "got {}", m.tau_broadcasts);
}

#[test]
fn appends_route_to_the_tail_node_and_match_the_single_process_stream() {
    // workers behind the reactor front end this time: τ broadcasts and
    // appends arrive on the ctl connection while a shard verb is in
    // flight on the data connection, so the worker must multiplex
    let w1 = TestServer::reactor(worker_service());
    let w2 = TestServer::reactor(worker_service());
    let mut rng = Xoshiro256::new(60);
    let reference = rng.normal_vec_f32(512);
    let coord = coordinator(reference.clone(), &[w1.addr.clone(), w2.addr.clone()]);
    let serial = search_only(reference.clone());

    // same raw samples into both: the cluster routes them to the tail
    // node's segment, the serial service into its streaming session —
    // both normalize with the same frozen startup stats
    for chunk in [rng.normal_vec_f32(64), rng.normal_vec_f32(37)] {
        let a = coord.append_blocking(chunk.clone(), AppendOptions::default()).unwrap();
        let b = serial.append_blocking(chunk, AppendOptions::default()).unwrap();
        assert_eq!(a.candidates, b.candidates, "candidate growth must agree");
        assert_eq!(a.stream_len, b.stream_len);
        assert_eq!((a.window, a.stride), (b.window, b.stride));
    }

    let q = rng.normal_vec_f32(24);
    for (k, exclusion) in [(1usize, 4usize), (3, 10)] {
        let opts = SearchOptions { k, exclusion, ..Default::default() };
        let serial_resp = serial
            .search_blocking(q.clone(), SearchOptions { stream: true, ..opts })
            .unwrap();
        let resp = coord.search_blocking(q.clone(), opts).unwrap();
        assert_hits_bit_identical(
            &resp.hits,
            &serial_resp.hits,
            &format!("post-append k={k}"),
        );
        assert_eq!(
            resp.stats.pruned_total() + resp.stats.dp_full,
            resp.stats.candidates
        );
    }
}

/// A byte-level TCP proxy that delays each `search.shard` request line
/// by `delay` before forwarding it (everything else — hello,
/// `segment.put`, τ broadcasts, and all responses — passes through
/// immediately): a deterministic stand-in for a node whose shard verbs
/// are slow, without also slowing the coordinator's control traffic.
fn delay_proxy(target: String, delay: Duration) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        for inbound in listener.incoming() {
            let Ok(inbound) = inbound else { break };
            let Ok(upstream) = TcpStream::connect(&target) else { break };
            let in_read = inbound.try_clone().unwrap();
            let mut up_write = upstream.try_clone().unwrap();
            std::thread::spawn(move || {
                let mut reader = BufReader::new(in_read);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {
                            if line.contains("\"op\":\"search.shard\"") {
                                std::thread::sleep(delay);
                            }
                            if up_write.write_all(line.as_bytes()).is_err()
                                || up_write.flush().is_err()
                            {
                                break;
                            }
                        }
                    }
                }
            });
            let mut out = inbound;
            std::thread::spawn(move || {
                let mut reader = BufReader::new(upstream);
                let mut line = String::new();
                loop {
                    line.clear();
                    match reader.read_line(&mut line) {
                        Ok(0) | Err(_) => break,
                        Ok(_) => {
                            if out.write_all(line.as_bytes()).is_err()
                                || out.flush().is_err()
                            {
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    addr
}

#[test]
fn a_slow_node_gets_its_chunks_stolen_without_changing_results() {
    let w1 = TestServer::blocking(worker_service());
    let w2 = TestServer::blocking(worker_service());
    // node 1 answers each shard verb ~150ms late; node 0 drains its own
    // four chunks in well under that and must steal node 1's backlog
    let slow = delay_proxy(w2.addr.clone(), Duration::from_millis(150));
    let mut rng = Xoshiro256::new(70);
    let reference = rng.normal_vec_f32(512);
    let coord = coordinator(reference.clone(), &[w1.addr.clone(), slow]);
    let serial = search_only(reference.clone());
    let (window, stride) = cluster_shape(reference.len());

    let q = rng.normal_vec_f32(32);
    let opts = SearchOptions { k: 2, exclusion: 6, ..Default::default() };
    let serial_resp = serial
        .search_blocking(q.clone(), SearchOptions { window, stride, ..opts })
        .unwrap();
    let resp = coord.search_blocking(q, opts).unwrap();

    assert_hits_bit_identical(&resp.hits, &serial_resp.hits, "with stealing");
    assert_eq!(
        resp.stats.pruned_total() + resp.stats.dp_full,
        resp.stats.candidates,
        "stolen chunks are accounted exactly once"
    );
    assert_eq!(resp.shards, 8, "every chunk executed, whoever ran it");
    let m = coord.metrics();
    assert!(
        m.shards_stolen >= 1,
        "the fast node must steal from the slow one, got {}",
        m.shards_stolen
    );
}

#[test]
fn worker_cluster_verbs_answer_directly_over_the_wire() {
    let ts = TestServer::blocking(worker_service());
    let mut client = Client::connect_negotiated(&ts.addr).unwrap();
    assert!(client.proto() >= 2);
    assert!(client.has_feature("search.shard"));

    // ship a segment that does not start at the global origin: 135
    // candidates based at global candidate 10, stride 2 (sample 20)
    let (window, stride, base) = (32usize, 2usize, 10u64);
    let mut rng = Xoshiro256::new(80);
    let samples = rng.normal_vec_f32(300);
    let candidates = (samples.len() - window) / stride + 1;
    let got = client
        .segment_put(5, base, base * stride as u64, window, stride, &samples)
        .unwrap();
    assert_eq!(got, candidates as u64);

    // the shard verb must reproduce an in-process run_shard bit-for-bit,
    // with hit coordinates mapped into the global sample frame
    let q = rng.normal_vec_f32(16);
    let (k, exclusion) = (2usize, 3usize);
    let cap = prune_heap_cap(k, exclusion, stride).min(candidates);
    let engine = StreamingEngine::new(&samples, window, stride, Dist::Sq).unwrap();
    let expected = run_shard(
        engine.index(),
        &q,
        Dist::Sq,
        k,
        cap,
        CascadeOpts::default(),
        0..candidates,
        f32::INFINITY,
        &RemoteTau::new(),
    );
    let f = client
        .search_shard(
            77,
            5,
            &q,
            k,
            exclusion,
            cap,
            base,
            base + candidates as u64,
            f32::INFINITY,
            0,
        )
        .unwrap();
    assert_eq!(f.sid, 77);
    assert_eq!(f.windows, expected.stats.candidates);
    assert_eq!(f.dp_full, expected.stats.dp_full);
    assert_eq!(f.tau.to_bits(), expected.tau.to_bits(), "τ survives the wire exactly");
    assert_eq!(f.hits.len(), expected.hits.len());
    let offset = (base as usize) * stride;
    for (a, b) in f.hits.iter().zip(&expected.hits) {
        assert_eq!(
            (a.start, a.end, a.cost.to_bits()),
            (b.start + offset, b.end + offset, b.cost.to_bits()),
            "wire hits in global coordinates"
        );
    }

    // τ broadcasts merge monotonically and ack with the cell value
    assert_eq!(client.tau(77, 3.5).unwrap(), 3.5);
    assert_eq!(client.tau(77, 9.0).unwrap(), 3.5, "looser τ never lands");
    assert_eq!(client.tau(77, 1.25).unwrap(), 1.25);

    // segment.append grows the segment's candidate count
    let extra = rng.normal_vec_f32(20);
    let grown = client.segment_append(5, &extra).unwrap();
    assert_eq!(grown, ((samples.len() + extra.len() - window) / stride + 1) as u64);

    // typed errors: unknown segment, and a sample offset off the grid
    let err = client
        .search_shard(1, 99, &q, 1, 1, 1, 0, 1, f32::INFINITY, 0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("[shape_mismatch]"), "{err}");
    assert!(err.contains("unknown segment"), "{err}");
    let err = client
        .segment_put(6, base, base * stride as u64 + 1, window, stride, &samples)
        .unwrap_err()
        .to_string();
    assert!(err.contains("[shape_mismatch]"), "{err}");
}

#[test]
fn wire_v1_sessions_stay_byte_identical_and_v2_adds_error_codes() {
    let blocking = TestServer::blocking(worker_service());
    let reactor = TestServer::reactor(worker_service());

    let exchange = |addr: &str, lines: &[&str]| -> Vec<String> {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        lines
            .iter()
            .map(|l| {
                stream.write_all(l.as_bytes()).unwrap();
                stream.write_all(b"\n").unwrap();
                stream.flush().unwrap();
                let mut line = String::new();
                assert!(reader.read_line(&mut line).unwrap() > 0, "closed on {l}");
                line.trim_end_matches('\n').to_string()
            })
            .collect()
    };

    // a session that never says hello speaks wire v1, byte-for-byte
    let v1 = ["{\"op\":\"ping\"}", "{\"op\":\"nope\"}", "{\"id\":4,\"op\":\"nope\"}"];
    // hello upgrades the SAME connection: errors gain the "code" member
    let v2 = ["{\"op\":\"hello\"}", "{\"op\":\"nope\"}", "{\"op\":\"ping\"}"];
    for ts in [&blocking, &reactor] {
        let a = exchange(&ts.addr, &v1);
        assert_eq!(a[0], "{\"ok\":true,\"pong\":true}");
        assert!(a[1].contains("\"ok\":false"), "{}", a[1]);
        assert!(
            !a[1].contains("\"code\""),
            "a v1 session must never see the v2 code member: {}",
            a[1]
        );
        assert!(a[2].starts_with("{\"id\":4,\"ok\":false"), "{}", a[2]);

        let b = exchange(&ts.addr, &v2);
        assert!(b[0].starts_with("{\"ok\":true,\"proto\":2,"), "{}", b[0]);
        assert!(b[0].contains("\"search.shard\""), "{}", b[0]);
        assert!(b[0].contains("\"errors.coded\""), "{}", b[0]);
        assert!(
            b[1].contains("\"code\":\"unsupported_verb\""),
            "post-hello errors carry the typed code: {}",
            b[1]
        );
        assert_eq!(b[2], "{\"ok\":true,\"pong\":true}", "happy verbs stay v1-shaped");
    }

    // and the two front ends agree byte-for-byte on both dialects
    assert_eq!(exchange(&blocking.addr, &v1), exchange(&reactor.addr, &v1));
    assert_eq!(exchange(&blocking.addr, &v2), exchange(&reactor.addr, &v2));
}

#[test]
fn a_local_backend_attached_in_process_drives_the_same_coordinator_paths() {
    let mut rng = Xoshiro256::new(90);
    let reference = rng.normal_vec_f32(400);
    let (window, stride) = cluster_shape(reference.len());

    // the backend indexes the service's frozen-frame (normalized) view
    let normalized = sdtw_repro::normalize::znormed(&reference);
    let backend = LocalBackend::new(&normalized, window, stride, 4, 2).unwrap();
    let mut svc = SdtwService::start(
        ServiceOptions { search_only: true, ..Default::default() },
        reference.clone(),
    )
    .unwrap();
    svc.attach_shard_backend(Arc::new(backend));
    let serial = search_only(reference);

    let q = rng.normal_vec_f32(24);
    let opts = SearchOptions { k: 3, exclusion: 8, ..Default::default() };
    let serial_resp = serial
        .search_blocking(q.clone(), SearchOptions { window, stride, ..opts })
        .unwrap();
    let resp = svc.search_blocking(q, opts).unwrap();
    assert_hits_bit_identical(&resp.hits, &serial_resp.hits, "local backend");
    assert_eq!(
        resp.stats.pruned_total() + resp.stats.dp_full,
        resp.stats.candidates
    );

    let m = svc.metrics();
    assert_eq!(m.cluster_nodes, 1, "the in-process backend is a one-node cluster");
    assert_eq!(m.tau_broadcasts, 0, "nothing remote to broadcast to");
    assert_eq!(m.shards_stolen, 0);
}
