//! Property tests for the unified DP-kernel layer (`dtw::kernel`): every
//! kernel — scalar, exact blocked scan at any width, lane-batched
//! lockstep at any lane count — must be **bit-identical** to the
//! `dtw::sdtw` oracle on every lane, and must make exactly the same
//! τ-abandonment decisions as `search::sdtw_window_abandoning`.  This is
//! the referee the whole refactor stands on: if these pass, re-pointing
//! the batch driver and the search cascade through the kernel layer
//! cannot have changed any result anywhere.

use sdtw_repro::dtw::kernel::{DpKernel, KernelSpec, Lane};
use sdtw_repro::dtw::{sdtw, Dist, Match};
use sdtw_repro::search::sdtw_window_abandoning;
use sdtw_repro::testutil::{check, GenCtx};

/// The kernel zoo a property run exercises: the scalar oracle wrapper,
/// scan widths spanning 1..=32 (plus wider-than-any-window), and lane
/// counts from degenerate 1 to wider than most batches.
fn specs(g: &mut GenCtx) -> Vec<KernelSpec> {
    vec![
        KernelSpec::SCALAR,
        KernelSpec::scan(1),
        KernelSpec::scan(g.usize_in(2, 32)),
        KernelSpec::scan(64),
        KernelSpec::lanes(1),
        KernelSpec::lanes(g.usize_in(2, 16)),
    ]
}

fn run_spec(
    spec: KernelSpec,
    lanes: &[Lane<'_>],
    abandon_at: f32,
    dist: Dist,
) -> Vec<Option<Match>> {
    let mut kernel = spec.instantiate();
    let mut out = Vec::new();
    kernel.run(lanes, abandon_at, dist, &mut out);
    out
}

#[test]
fn prop_every_kernel_bit_identical_to_oracle() {
    check(501, 120, |g| {
        // a ragged batch: random lane count, each lane its own shape
        let n_lanes = g.usize_in(1, 13);
        let data: Vec<(Vec<f32>, Vec<f32>)> = (0..n_lanes)
            .map(|_| (g.vec_f32(1, 12), g.vec_f32(1, 40)))
            .collect();
        let lanes: Vec<Lane<'_>> = data
            .iter()
            .map(|(q, w)| Lane { query: q, window: w })
            .collect();
        let dist = if g.usize_in(0, 1) == 0 { Dist::Sq } else { Dist::Abs };
        for spec in specs(g) {
            let out = run_spec(spec, &lanes, f32::INFINITY, dist);
            if out.len() != lanes.len() {
                return Err(format!("{spec:?}: {} results for {} lanes", out.len(), lanes.len()));
            }
            for (i, ((q, w), got)) in data.iter().zip(&out).enumerate() {
                let want = sdtw(q, w, dist);
                let got = got.ok_or_else(|| format!("{spec:?} lane {i}: abandoned at τ=∞"))?;
                if got.cost.to_bits() != want.cost.to_bits() {
                    return Err(format!(
                        "{spec:?} lane {i}: cost {} vs oracle {} (not bit-identical)",
                        got.cost, want.cost
                    ));
                }
                if got.end != want.end {
                    return Err(format!(
                        "{spec:?} lane {i}: end {} vs oracle {}",
                        got.end, want.end
                    ));
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_tau_abandonment_agrees_with_reference_dp() {
    check(502, 100, |g| {
        // one query against many windows — the cascade's survivor shape
        let q = g.vec_f32(2, 10);
        let n_lanes = g.usize_in(1, 11);
        let windows: Vec<Vec<f32>> = (0..n_lanes).map(|_| g.vec_f32(2, 24)).collect();
        let lanes: Vec<Lane<'_>> = windows
            .iter()
            .map(|w| Lane { query: &q, window: w })
            .collect();
        // τ spanning "abandons everything" to "abandons nothing"
        let tau = g.f32_in(0.0, 25.0);
        for spec in specs(g) {
            let out = run_spec(spec, &lanes, tau, Dist::Sq);
            for (i, (w, got)) in windows.iter().zip(&out).enumerate() {
                let want = sdtw_window_abandoning(&q, w, tau, Dist::Sq);
                match (got, want) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        if a.cost.to_bits() != b.cost.to_bits() || a.end != b.end {
                            return Err(format!(
                                "{spec:?} lane {i} τ={tau}: ({}, {}) vs ({}, {})",
                                a.cost, a.end, b.cost, b.end
                            ));
                        }
                    }
                    (got, want) => {
                        return Err(format!(
                            "{spec:?} lane {i} τ={tau}: abandonment disagrees \
                             (kernel {got:?}, reference {want:?})"
                        ))
                    }
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_ragged_tail_batches_survive_lane_chunking() {
    // survivors % lanes != 0 by construction: lane counts that never
    // divide the batch, so every run has a partial tail chunk
    check(503, 80, |g| {
        let lane_cap = g.usize_in(2, 8);
        let n_lanes = lane_cap * g.usize_in(1, 3) + g.usize_in(1, lane_cap - 1);
        debug_assert!(n_lanes % lane_cap != 0);
        let data: Vec<(Vec<f32>, Vec<f32>)> = (0..n_lanes)
            .map(|_| (g.vec_f32(1, 10), g.vec_f32(1, 30)))
            .collect();
        let lanes: Vec<Lane<'_>> = data
            .iter()
            .map(|(q, w)| Lane { query: q, window: w })
            .collect();
        let out = run_spec(KernelSpec::lanes(lane_cap), &lanes, f32::INFINITY, Dist::Sq);
        for (i, ((q, w), got)) in data.iter().zip(&out).enumerate() {
            let want = sdtw(q, w, Dist::Sq);
            let got = got.ok_or_else(|| format!("lane {i}: abandoned at τ=∞"))?;
            if got.cost.to_bits() != want.cost.to_bits() || got.end != want.end {
                return Err(format!(
                    "cap {lane_cap} lane {i}/{n_lanes}: ({}, {}) vs ({}, {})",
                    got.cost, got.end, want.cost, want.end
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_scan_widths_1_to_32_all_exact() {
    check(504, 40, |g| {
        let q = g.vec_f32(1, 14);
        let w = g.vec_f32(1, 48);
        let want = sdtw(&q, &w, Dist::Sq);
        let lanes = [Lane { query: &q, window: &w }];
        for width in 1..=32usize {
            let out = run_spec(KernelSpec::scan(width), &lanes, f32::INFINITY, Dist::Sq);
            let got = out[0].ok_or_else(|| format!("width {width}: abandoned at τ=∞"))?;
            if got.cost.to_bits() != want.cost.to_bits() || got.end != want.end {
                return Err(format!(
                    "width {width}: ({}, {}) vs oracle ({}, {})",
                    got.cost, got.end, want.cost, want.end
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_batch_driver_identical_for_every_kernel() {
    // the re-pointed sdtw_batch_cpu: every kernel, every thread count,
    // bit-identical to the oracle on each query of a uniform batch
    check(505, 40, |g| {
        let b = g.usize_in(1, 9);
        let m = g.usize_in(1, 10);
        let qs: Vec<f32> = (0..b).flat_map(|_| g.vec_f32(m, m)).collect();
        debug_assert_eq!(qs.len(), b * m);
        let r = g.vec_f32(4, 64);
        for spec in specs(g) {
            for threads in [1usize, 3] {
                let got = sdtw_repro::dtw::batch::sdtw_batch_kernel(
                    &qs, m, &r, Dist::Sq, threads, spec,
                );
                for i in 0..b {
                    let want = sdtw(&qs[i * m..(i + 1) * m], &r, Dist::Sq);
                    if got[i].cost.to_bits() != want.cost.to_bits() || got[i].end != want.end {
                        return Err(format!(
                            "{spec:?} t={threads} q{i}: ({}, {}) vs ({}, {})",
                            got[i].cost, got[i].end, want.cost, want.end
                        ));
                    }
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_cascade_topk_invariant_under_kernel_choice() {
    // the end-to-end claim: the search engine returns bit-identical
    // top-K hits no matter which kernel executes its survivors
    use std::sync::Arc;
    use sdtw_repro::search::{CascadeOpts, SearchEngine};
    check(506, 40, |g| {
        let r = Arc::new(g.vec_f32(60, 160));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(r.len()));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let base = engine
            .search_opts(&q, k, exclusion, CascadeOpts::default(), 1)
            .map_err(|e| e.to_string())?;
        for spec in specs(g) {
            let opts = CascadeOpts::default().with_kernel(spec);
            let got = engine
                .search_opts(&q, k, exclusion, opts, 1)
                .map_err(|e| e.to_string())?;
            if got.hits.len() != base.hits.len() {
                return Err(format!(
                    "{spec:?}: {} hits vs {}",
                    got.hits.len(),
                    base.hits.len()
                ));
            }
            for (a, b) in got.hits.iter().zip(&base.hits) {
                if a.start != b.start || a.end != b.end || a.cost.to_bits() != b.cost.to_bits()
                {
                    return Err(format!("{spec:?}: hit {a:?} vs {b:?}"));
                }
            }
            let s = got.stats;
            if s.pruned_total() + s.dp_full != s.candidates {
                return Err(format!("{spec:?}: counters do not partition: {s:?}"));
            }
            if s.survivors() > 0 && s.survivor_batches == 0 {
                return Err(format!("{spec:?}: survivors without a batch flush: {s:?}"));
            }
        }
        Ok(())
    })
    .unwrap();
}
