//! Property tests for the streaming search subsystem: after *any*
//! append schedule, the incremental index is bit-identical to a batch
//! `ReferenceIndex::build` on the final prefix (envelopes, slices,
//! candidate counts), and every search path over it — serial or
//! sharded, any DP kernel, delta or full — returns the same hits with
//! partition-consistent counters.  The k = 0 invariant is pinned on
//! every path.

use std::sync::Arc;

use sdtw_repro::dtw::{Dist, KernelSpec};
use sdtw_repro::search::envelope::{sliding_min_max, StreamingExtrema};
use sdtw_repro::search::{
    CascadeOpts, Hit, ReferenceIndex, SearchEngine, StreamingEngine, StreamingIndex,
};
use sdtw_repro::testutil::check;

/// Random-walk style series (level drift makes envelope bounds bite).
fn walk(g: &mut sdtw_repro::testutil::GenCtx, lo: usize, hi: usize) -> Vec<f32> {
    let base = g.vec_f32(lo, hi);
    let mut level = 0f32;
    base.iter()
        .map(|&step| {
            level += step * 0.5;
            level
        })
        .collect()
}

fn assert_bit_identical(label: &str, a: &[Hit], b: &[Hit]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: {} vs {} hits", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.start != y.start || x.end != y.end || x.cost.to_bits() != y.cost.to_bits() {
            return Err(format!("{label}: hit {i} differs: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_streaming_extrema_matches_batch_for_all_prefixes_and_append_lengths() {
    // the satellite contract: incremental == batch for every prefix,
    // driven by appends of every length 1..=17
    check(501, 60, |g| {
        let x = walk(g, 1, 180);
        let window = g.usize_in(1, x.len());
        for append_len in 1..=17usize {
            let mut ext = StreamingExtrema::new(window);
            let mut lo = Vec::new();
            let mut hi = Vec::new();
            let mut at = 0usize;
            while at < x.len() {
                let end = (at + append_len).min(x.len());
                for &v in &x[at..end] {
                    if let Some((l, h)) = ext.push(v) {
                        lo.push(l);
                        hi.push(h);
                    }
                }
                at = end;
                // prefix check after every simulated append
                if at >= window {
                    let (blo, bhi) = sliding_min_max(&x[..at], window);
                    if lo.len() != blo.len() {
                        return Err(format!(
                            "append_len={append_len} prefix={at}: {} vs {} outputs",
                            lo.len(),
                            blo.len()
                        ));
                    }
                    for (s, ((&a, &b), (&c, &d))) in
                        lo.iter().zip(&hi).zip(blo.iter().zip(&bhi)).enumerate()
                    {
                        if a.to_bits() != c.to_bits() || b.to_bits() != d.to_bits() {
                            return Err(format!(
                                "append_len={append_len} prefix={at} start={s}: \
                                 ({a}, {b}) vs ({c}, {d})"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_streaming_index_bit_identical_to_batch_rebuild() {
    // any (window, stride), any random append schedule: the incremental
    // index must equal ReferenceIndex::build on the final prefix
    check(502, 60, |g| {
        let x = walk(g, 20, 250);
        let window = g.usize_in(1, x.len().min(40));
        let stride = g.usize_in(1, 4);
        let seed_len = g.usize_in(window, x.len());
        let mut ix = StreamingIndex::new(&x[..seed_len], window, stride)
            .map_err(|e| e.to_string())?;
        let mut at = seed_len;
        let mut prev_envelopes: Vec<(u32, u32)> = Vec::new();
        while at < x.len() {
            let end = (at + g.usize_in(1, 30)).min(x.len());
            ix.append(&x[at..end]);
            at = end;
            // regression: appended samples never perturb pre-existing
            // candidate envelopes
            for (t, &(lo, hi)) in prev_envelopes.iter().enumerate() {
                let (l, h) = ix.envelope(t);
                if l.to_bits() != lo || h.to_bits() != hi {
                    return Err(format!("append perturbed candidate {t}'s envelope"));
                }
            }
            prev_envelopes = (0..ix.candidates())
                .map(|t| {
                    let (l, h) = ix.envelope(t);
                    (l.to_bits(), h.to_bits())
                })
                .collect();
        }
        let batch = ReferenceIndex::build(Arc::new(x.clone()), window, stride)
            .map_err(|e| e.to_string())?;
        if ix.candidates() != batch.candidates() {
            return Err(format!(
                "candidates: streaming {} vs batch {} (w={window} s={stride})",
                ix.candidates(),
                batch.candidates()
            ));
        }
        for t in 0..ix.candidates() {
            if ix.start(t) != batch.start(t) || ix.window_slice(t) != batch.window_slice(t) {
                return Err(format!("candidate {t}: start/slice mismatch"));
            }
            let (a, b) = ix.envelope(t);
            let (c, d) = batch.envelope(t);
            if a.to_bits() != c.to_bits() || b.to_bits() != d.to_bits() {
                return Err(format!("candidate {t}: envelope ({a},{b}) vs ({c},{d})"));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_streaming_search_bit_identical_across_kernels_and_sharding() {
    // the acceptance invariant: streaming ≡ batch-rebuild for hits AND
    // counters, over scalar/scan/lane kernels and serial + sharded
    // execution, after a randomized append schedule
    check(503, 40, |g| {
        let x = walk(g, 60, 260);
        let window = g.usize_in(4, x.len().min(24));
        let stride = g.usize_in(1, 2);
        let k = g.usize_in(1, 4);
        let exclusion = g.usize_in(1, window);
        let m = g.usize_in(3, 12);
        let q = g.vec_f32(m, m);

        let seed_len = g.usize_in(window, x.len());
        let mut se = StreamingEngine::new(&x[..seed_len], window, stride, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let mut at = seed_len;
        while at < x.len() {
            let end = (at + g.usize_in(1, 60)).min(x.len());
            se.append(&x[at..end]);
            at = end;
        }
        let batch = SearchEngine::new(Arc::new(x.clone()), window, stride, Dist::Sq)
            .map_err(|e| e.to_string())?;

        for spec in [
            KernelSpec::SCALAR,
            KernelSpec::scan(g.usize_in(1, 9)),
            KernelSpec::lanes(g.usize_in(1, 8)),
        ] {
            let opts = CascadeOpts::default().with_kernel(spec);
            let want = batch
                .search_opts(&q, k, exclusion, opts, 1)
                .map_err(|e| e.to_string())?;
            // serial full search: identical hits AND identical counters
            // (same cascade over the same candidates)
            let got = se
                .search(&q, k, exclusion, opts)
                .map_err(|e| e.to_string())?;
            assert_bit_identical(&format!("serial {spec:?}"), &got.hits, &want.hits)?;
            if got.stats != want.stats {
                return Err(format!(
                    "{spec:?}: counters diverged: {:?} vs {:?}",
                    got.stats, want.stats
                ));
            }
            if got.stats.pruned_total() + got.stats.dp_full != got.stats.candidates {
                return Err(format!("{spec:?}: counters don't partition: {:?}", got.stats));
            }
            // sharded over the streaming index: identical hits, merged
            // counters partition the space
            let shards = g.usize_in(2, 6);
            let threads = g.usize_in(1, 4);
            let sharded = se
                .search_sharded(&q, k, exclusion, opts, shards, threads)
                .map_err(|e| e.to_string())?;
            assert_bit_identical(
                &format!("sharded {spec:?} ({shards}x{threads})"),
                &sharded.hits,
                &want.hits,
            )?;
            if sharded.stats.pruned_total() + sharded.stats.dp_full != sharded.stats.candidates
            {
                return Err(format!(
                    "sharded {spec:?}: counters don't partition: {:?}",
                    sharded.stats
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_delta_search_bit_identical_to_full_rebuild_at_every_step() {
    // interleaved appends and delta searches: each delta's picks must
    // equal a from-scratch rebuild + search over the current prefix
    check(504, 40, |g| {
        let x = walk(g, 60, 300);
        let window = g.usize_in(4, x.len().min(20));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let m = g.usize_in(3, 10);
        let q = g.vec_f32(m, m);
        let seed_len = g.usize_in(window, x.len());
        let mut se = StreamingEngine::new(&x[..seed_len], window, 1, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let mut at = seed_len;
        loop {
            let d = se
                .search_delta(&q, k, exclusion, CascadeOpts::default())
                .map_err(|e| e.to_string())?;
            let want = SearchEngine::new(Arc::new(x[..at].to_vec()), window, 1, Dist::Sq)
                .map_err(|e| e.to_string())?
                .search(&q, k, exclusion)
                .map_err(|e| e.to_string())?;
            assert_bit_identical(&format!("delta at {at}"), &d.outcome.hits, &want.hits)?;
            if d.scanned + d.skipped != se.index().candidates() as u64 {
                return Err(format!(
                    "at {at}: scanned {} + skipped {} != candidates {}",
                    d.scanned,
                    d.skipped,
                    se.index().candidates()
                ));
            }
            if d.outcome.stats.pruned_total() + d.outcome.stats.dp_full
                != d.outcome.stats.candidates
            {
                return Err(format!(
                    "at {at}: delta counters don't partition: {:?}",
                    d.outcome.stats
                ));
            }
            if at >= x.len() {
                break;
            }
            let end = (at + g.usize_in(1, 50)).min(x.len());
            se.append(&x[at..end]);
            at = end;
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_k_zero_partition_invariant_on_every_path() {
    // k = 0 returns nothing but must account every candidate (the
    // `skipped` counter) on the serial, sharded, and streaming paths
    check(505, 25, |g| {
        let x = walk(g, 30, 150);
        let window = g.usize_in(2, x.len().min(16));
        let m = g.usize_in(2, 8);
        let q = g.vec_f32(m, m);
        let batch = SearchEngine::new(Arc::new(x.clone()), window, 1, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let candidates = batch.index().candidates() as u64;

        let serial = batch
            .search_opts(&q, 0, 3, CascadeOpts::default(), 1)
            .map_err(|e| e.to_string())?;
        let sharded = batch
            .search_sharded(&q, 0, 3, CascadeOpts::default(), g.usize_in(2, 5), 2)
            .map_err(|e| e.to_string())?;
        let mut se = StreamingEngine::new(&x, window, 1, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let delta = se
            .search_delta(&q, 0, 3, CascadeOpts::default())
            .map_err(|e| e.to_string())?;

        for (label, hits_empty, stats) in [
            ("serial", serial.hits.is_empty(), serial.stats),
            ("sharded", sharded.hits.is_empty(), sharded.stats),
            ("streaming", delta.outcome.hits.is_empty(), delta.outcome.stats),
        ] {
            if !hits_empty {
                return Err(format!("{label}: k=0 returned hits"));
            }
            if stats.candidates != candidates {
                return Err(format!(
                    "{label}: candidates {} != {candidates}",
                    stats.candidates
                ));
            }
            if stats.skipped != candidates || stats.dp_full != 0 {
                return Err(format!("{label}: k=0 stats not all-skipped: {stats:?}"));
            }
            if stats.pruned_total() + stats.dp_full != stats.candidates {
                return Err(format!("{label}: counters don't partition: {stats:?}"));
            }
        }
        // per-shard reports partition too
        for s in &sharded.shards {
            if s.stats.pruned_total() + s.stats.dp_full != s.stats.candidates {
                return Err(format!("shard {}: k=0 counters don't partition", s.shard));
            }
        }
        Ok(())
    })
    .unwrap();
}
