//! Integration: the event-driven multiplexed front end over real
//! sockets, served **search-only** (no compiled artifacts — these tests
//! never skip).  Covers pipelining with id echo, 64 concurrent
//! connections on a 2-thread executor pool, slow-loris isolation,
//! oversized-frame containment on both front ends, and byte-identical
//! responses between the reactor and the blocking server.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use sdtw_repro::coordinator::{SdtwService, SearchOptions, ServiceOptions};
use sdtw_repro::server::{
    Client, Reactor, ReactorOptions, Request, RequestId, Response, Server, DEFAULT_MAX_FRAME,
};
use sdtw_repro::util::rng::Xoshiro256;

fn service(reflen: usize) -> Arc<SdtwService> {
    let mut rng = Xoshiro256::new(42);
    Arc::new(
        SdtwService::start(
            ServiceOptions { search_only: true, ..Default::default() },
            rng.normal_vec_f32(reflen),
        )
        .unwrap(),
    )
}

struct TestServer {
    addr: String,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

impl TestServer {
    fn reactor(reflen: usize, opts: ReactorOptions) -> TestServer {
        let r = Reactor::bind(service(reflen), "127.0.0.1:0", opts).unwrap();
        let addr = r.local_addr().unwrap().to_string();
        let stop = r.stop_flag();
        TestServer { addr, stop, join: Some(std::thread::spawn(move || r.serve())) }
    }

    fn blocking(reflen: usize, max_frame: usize) -> TestServer {
        let mut s = Server::bind(service(reflen), "127.0.0.1:0").unwrap();
        s.set_max_frame(max_frame);
        let addr = s.local_addr().unwrap().to_string();
        let stop = s.stop_flag();
        TestServer { addr, stop, join: Some(std::thread::spawn(move || s.serve())) }
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn raw_connect(addr: &str) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    let reader = BufReader::new(stream.try_clone().unwrap());
    (stream, reader)
}

fn read_line(reader: &mut BufReader<TcpStream>) -> String {
    let mut line = String::new();
    let n = reader.read_line(&mut line).unwrap();
    assert!(n > 0, "server closed the connection unexpectedly");
    line.trim_end_matches('\n').to_string()
}

#[test]
fn pipelined_ids_echo_and_search_stays_bit_identical_to_serial() {
    let ts =
        TestServer::reactor(512, ReactorOptions { threads: 2, ..Default::default() });
    let mut rng = Xoshiro256::new(7);
    let q = rng.normal_vec_f32(32);
    let opts = SearchOptions { k: 2, ..Default::default() };

    // serial reference: one request at a time on its own connection
    let mut serial = Client::connect(&ts.addr).unwrap();
    let reference = serial.search(&q, opts).unwrap();

    // pipelined: fire everything before reading anything
    let mut piped = Client::connect(&ts.addr).unwrap();
    let search = Request::Search { query: q.clone(), options: opts };
    for i in 0..8i64 {
        let req = if i % 2 == 0 { Request::Ping } else { search.clone() };
        piped.send(&req, Some(&RequestId::Int(i))).unwrap();
    }
    for i in 0..8i64 {
        let (id, resp) = piped.recv().unwrap();
        assert_eq!(id, Some(RequestId::Int(i)), "responses in request order, ids echoed");
        match resp {
            Response::Pong => assert_eq!(i % 2, 0, "slot {i}"),
            Response::Search(s) => {
                assert_eq!(i % 2, 1, "slot {i}");
                assert_eq!(s.hits.len(), reference.hits.len());
                for (a, b) in s.hits.iter().zip(&reference.hits) {
                    assert_eq!(a.start, b.start);
                    assert_eq!(a.end, b.end);
                    assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "bit-identical hits");
                }
                assert_eq!(s.windows, reference.windows);
                assert_eq!(s.pruned_kim, reference.pruned_kim);
                assert_eq!(s.pruned_keogh, reference.pruned_keogh);
                assert_eq!(s.dp_full, reference.dp_full);
            }
            other => panic!("slot {i}: unexpected response {other:?}"),
        }
    }
}

#[test]
fn sixty_four_pipelined_connections_share_a_fixed_executor_pool() {
    let ts = TestServer::reactor(
        256,
        ReactorOptions { threads: 2, max_inflight: 8, ..Default::default() },
    );
    let mut rng = Xoshiro256::new(11);
    let q = rng.normal_vec_f32(24);
    let opts = SearchOptions { k: 1, ..Default::default() };

    let mut serial = Client::connect(&ts.addr).unwrap();
    let reference = serial.search(&q, opts).unwrap();

    let n = 64usize;
    let mut conns: Vec<Client> =
        (0..n).map(|_| Client::connect(&ts.addr).unwrap()).collect();
    let search = Request::Search { query: q.clone(), options: opts };
    for (c, client) in conns.iter_mut().enumerate() {
        for i in 0..3i64 {
            let req = match i {
                0 => Request::Ping,
                1 => search.clone(),
                _ => Request::Info,
            };
            client.send(&req, Some(&RequestId::Int(c as i64 * 10 + i))).unwrap();
        }
    }
    for (c, client) in conns.iter_mut().enumerate() {
        for i in 0..3i64 {
            let (id, resp) = client.recv().unwrap();
            assert_eq!(id, Some(RequestId::Int(c as i64 * 10 + i)), "conn {c} slot {i}");
            match (i, resp) {
                (0, Response::Pong) => {}
                (1, Response::Search(s)) => {
                    assert_eq!(s.hits.len(), reference.hits.len(), "conn {c}");
                    for (a, b) in s.hits.iter().zip(&reference.hits) {
                        assert_eq!(
                            (a.start, a.end, a.cost.to_bits()),
                            (b.start, b.end, b.cost.to_bits()),
                            "conn {c}: hits must be bit-identical to serial"
                        );
                    }
                    assert_eq!(s.windows, reference.windows, "conn {c}");
                }
                (2, Response::Info { .. }) => {}
                (slot, other) => panic!("conn {c} slot {slot}: unexpected {other:?}"),
            }
        }
    }

    // the burst really multiplexed: pipelining observed, every
    // connection still open and counted at the edge
    let m = serial.metrics().unwrap();
    assert!(m.requests_pipelined > 0, "pipelined bursts must be counted");
    assert_eq!(m.conns_open, n as u64 + 1, "64 burst clients + the serial one");
}

#[test]
fn a_slow_loris_sender_does_not_stall_other_connections() {
    // one executor + one poller: if a half-open frame blocked anything,
    // the fast client below could never complete
    let ts =
        TestServer::reactor(256, ReactorOptions { threads: 1, ..Default::default() });
    let (mut slow, mut slow_reader) = raw_connect(&ts.addr);
    slow.write_all(b"{\"id\":9,\"op\":\"pi").unwrap();
    slow.flush().unwrap();

    // with the slow frame still open, another connection is served
    let mut fast = Client::connect(&ts.addr).unwrap();
    for _ in 0..20 {
        fast.ping().unwrap();
    }

    // the drip-fed frame still completes correctly afterwards
    slow.write_all(b"ng\"}\n").unwrap();
    slow.flush().unwrap();
    assert_eq!(read_line(&mut slow_reader), "{\"id\":9,\"ok\":true,\"pong\":true}");
}

#[test]
fn oversized_frames_error_and_the_connection_survives_on_both_edges() {
    let reactor = TestServer::reactor(
        256,
        ReactorOptions { max_frame: 64, ..Default::default() },
    );
    let blocking = TestServer::blocking(256, 64);
    for (edge, ts) in [("reactor", &reactor), ("blocking", &blocking)] {
        let (mut stream, mut reader) = raw_connect(&ts.addr);
        let flood = "x".repeat(200);
        stream.write_all(flood.as_bytes()).unwrap();
        stream.write_all(b"\n{\"id\":1,\"op\":\"ping\"}\n").unwrap();
        stream.flush().unwrap();

        let err = read_line(&mut reader);
        assert!(err.contains("\"ok\":false"), "{edge}: oversized must error: {err}");
        assert!(err.contains("max-frame"), "{edge}: error names the cap: {err}");
        // the same connection keeps serving
        assert_eq!(read_line(&mut reader), "{\"id\":1,\"ok\":true,\"pong\":true}", "{edge}");

        let mut client = Client::connect(&ts.addr).unwrap();
        let m = client.metrics().unwrap();
        assert_eq!(m.frames_oversized, 1, "{edge}: counter surfaces on the wire");
    }
}

#[test]
fn both_front_ends_answer_byte_identically() {
    let reactor = TestServer::reactor(512, ReactorOptions::default());
    let blocking = TestServer::blocking(512, DEFAULT_MAX_FRAME);
    // deterministic lines only (no latency fields): happy verbs with and
    // without ids, wire garbage, a request-level error, a non-object
    let lines = [
        "{\"op\":\"ping\"}",
        "{\"id\":7,\"op\":\"ping\"}",
        "{\"id\":\"q-1\",\"op\":\"info\"}",
        "not json at all",
        "{\"op\":\"nope\"}",
        "{\"id\":3}",
        "[1,2,3]",
    ];
    let collect = |addr: &str| -> Vec<String> {
        let (mut s, mut r) = raw_connect(addr);
        lines
            .iter()
            .map(|l| {
                s.write_all(l.as_bytes()).unwrap();
                s.write_all(b"\n").unwrap();
                s.flush().unwrap();
                read_line(&mut r)
            })
            .collect()
    };
    let a = collect(&reactor.addr);
    let b = collect(&blocking.addr);
    assert_eq!(a, b, "the two front ends must answer byte-identically");
    assert!(a[0].contains("pong"));
    assert!(a[1].starts_with("{\"id\":7,"), "id leads the response: {}", a[1]);
    assert!(a[3].contains("bad request"), "wire garbage: {}", a[3]);
    assert!(
        a[5].starts_with("{\"id\":3,\"ok\":false"),
        "id echoes even on request-level errors: {}",
        a[5]
    );
}
