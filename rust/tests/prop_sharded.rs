//! Property and stress tests for the sharded parallel search executor:
//! bit-identical agreement with the serial engine (and brute force) over
//! random shapes, shard counts — including more shards than candidates —
//! and thread counts, plus a concurrency stress test showing that shared
//! threshold tightening never drops a true hit.

use std::sync::Arc;

use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::search::{select_topk, CascadeOpts, Hit, ReferenceIndex, SearchEngine};
use sdtw_repro::testutil::check;
use sdtw_repro::util::rng::Xoshiro256;

/// Random-walk style series (level drift makes envelope bounds bite).
fn walk(g: &mut sdtw_repro::testutil::GenCtx, lo: usize, hi: usize) -> Vec<f32> {
    let base = g.vec_f32(lo, hi);
    let mut level = 0f32;
    base.iter()
        .map(|&step| {
            level += step * 0.5;
            level
        })
        .collect()
}

fn brute_topk(query: &[f32], index: &ReferenceIndex, k: usize, exclusion: usize) -> Vec<Hit> {
    let hits: Vec<Hit> = (0..index.candidates())
        .map(|t| {
            let m = sdtw(query, index.window_slice(t), Dist::Sq);
            let start = index.start(t);
            Hit { start, end: start + m.end, cost: m.cost }
        })
        .collect();
    select_topk(&hits, k, exclusion)
}

fn assert_bit_identical(label: &str, a: &[Hit], b: &[Hit]) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{label}: {} vs {} hits", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if x.start != y.start || x.end != y.end || x.cost.to_bits() != y.cost.to_bits() {
            return Err(format!("{label}: hit {i} differs: {x:?} vs {y:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_sharded_executor_bit_identical_to_serial_and_brute() {
    // the acceptance invariant: any shard count (including far more
    // shards than candidates), any thread count, any stride/K/exclusion
    check(401, 80, |g| {
        let r = Arc::new(walk(g, 50, 220));
        let m = g.usize_in(3, 12);
        let window = g.usize_in(m, (m + 12).min(r.len()));
        let stride = g.usize_in(1, 3);
        let k = g.usize_in(1, 5);
        let exclusion = g.usize_in(0, window);
        let q = g.vec_f32(m, m);
        let engine = SearchEngine::new(r, window, stride, Dist::Sq)
            .map_err(|e| e.to_string())?;
        let candidates = engine.index().candidates();
        let brute = brute_topk(&q, engine.index(), k, exclusion);
        let serial = engine
            .search(&q, k, exclusion)
            .map_err(|e| e.to_string())?;
        assert_bit_identical("serial vs brute", &serial.hits, &brute)?;

        // the single-shard single-thread run is the serial τ reference:
        // every parallel configuration must publish the same final τ
        // bit-for-bit (it is the cap-th smallest true cost — see
        // ShardedOutcome::final_tau)
        let reference_tau = engine
            .search_sharded(&q, k, exclusion, CascadeOpts::default(), 1, 1)
            .map_err(|e| e.to_string())?
            .final_tau;

        // shard counts spanning 1, a few, the candidate count, and beyond
        for shards in [1, g.usize_in(2, 8), candidates.max(1), candidates + 9] {
            let threads = g.usize_in(1, 4);
            let out = engine
                .search_sharded(&q, k, exclusion, CascadeOpts::default(), shards, threads)
                .map_err(|e| e.to_string())?;
            if out.final_tau.to_bits() != reference_tau.to_bits() {
                return Err(format!(
                    "{shards} shards × {threads} threads: final τ {} != serial τ {}",
                    out.final_tau, reference_tau
                ));
            }
            assert_bit_identical(
                &format!("{shards} shards × {threads} threads"),
                &out.hits,
                &brute,
            )?;
            if out.stats.pruned_total() + out.stats.dp_full != out.stats.candidates {
                return Err(format!(
                    "merged counters don't partition candidates: {:?}",
                    out.stats
                ));
            }
            if out.stats.candidates != candidates as u64 {
                return Err(format!(
                    "shards saw {} candidates, index has {candidates}",
                    out.stats.candidates
                ));
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn prop_sharded_brute_opts_and_stage_toggles_stay_exact() {
    // every cascade stage combination remains lossless under sharding
    check(402, 30, |g| {
        let r = Arc::new(walk(g, 60, 160));
        let m = g.usize_in(4, 10);
        let window = g.usize_in(m, (m + 8).min(r.len()));
        let k = g.usize_in(1, 3);
        let exclusion = g.usize_in(1, window);
        let shards = g.usize_in(2, 6);
        let q = g.vec_f32(m, m);
        let engine =
            SearchEngine::new(r, window, 1, Dist::Sq).map_err(|e| e.to_string())?;
        let brute = brute_topk(&q, engine.index(), k, exclusion);
        for kim in [false, true] {
            for keogh in [false, true] {
                for abandon in [false, true] {
                    let opts = CascadeOpts { kim, keogh, abandon, ..Default::default() };
                    let out = engine
                        .search_sharded(&q, k, exclusion, opts, shards, 3)
                        .map_err(|e| e.to_string())?;
                    assert_bit_identical(&format!("opts {opts:?}"), &out.hits, &brute)?;
                }
            }
        }
        Ok(())
    })
    .unwrap();
}

#[test]
fn stress_concurrent_tightening_never_drops_a_true_hit() {
    // one large planted workload, hammered repeatedly at high shard and
    // thread counts: the shared τ races across workers on every run, and
    // every run must still return exactly the brute-force top-K
    let mut rng = Xoshiro256::new(99);
    let n = 6000;
    let m = 48;
    let window = 72;
    let mut level = 0f64;
    let mut reference: Vec<f32> = (0..n)
        .map(|_| {
            level += rng.normal() * 0.4;
            level as f32
        })
        .collect();
    let query: Vec<f32> = rng.normal_vec_f32(m);
    for at in [700usize, 2100, 3500, 4900] {
        let stretch = rng.uniform(0.85, 1.2);
        sdtw_repro::datagen::embed_query(&mut reference, &query, at, stretch, 0.05, &mut rng);
    }
    let rn = Arc::new(sdtw_repro::normalize::znormed(&reference));
    let qn = sdtw_repro::normalize::znormed(&query);
    let engine = SearchEngine::new(rn, window, 1, Dist::Sq).unwrap();

    let k = 4;
    let exclusion = window / 2;
    let brute = brute_topk(&qn, engine.index(), k, exclusion);
    assert_eq!(brute.len(), k, "workload must fill all K slots");

    // serial τ reference: the racing runs below must land on the same
    // published τ bit-for-bit — the lost-update regression assertion
    // for SharedThreshold::tighten (a load-then-store publish can leave
    // a looser τ; the CAS min-loop cannot)
    let serial_tau = engine
        .search_sharded(&qn, k, exclusion, CascadeOpts::default(), 1, 1)
        .unwrap()
        .final_tau;
    assert!(serial_tau.is_finite(), "planted workload must fill the τ heap");

    let mut tightened_at_least_once = false;
    for run in 0..20 {
        let shards = [2, 4, 8, 16][run % 4];
        let out = engine
            .search_sharded(&qn, k, exclusion, CascadeOpts::default(), shards, 8)
            .unwrap();
        assert_eq!(
            out.hits, brute,
            "run {run} ({shards} shards): sharded top-K diverged from brute force"
        );
        assert_eq!(
            out.final_tau.to_bits(),
            serial_tau.to_bits(),
            "run {run} ({shards} shards): final τ {} != serial τ {serial_tau}",
            out.final_tau
        );
        tightened_at_least_once |= out.tau_tightenings > 0;
        // pruning must actually engage — the threshold the workers race
        // over is doing real work, not vacuously +inf
        assert!(
            out.stats.prune_fraction() > 0.3,
            "run {run}: prune fraction {:.2} too low for a planted workload",
            out.stats.prune_fraction()
        );
    }
    assert!(
        tightened_at_least_once,
        "shared threshold never tightened across 20 sharded runs"
    );
}
