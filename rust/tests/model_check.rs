//! Integration surface for the in-tree model checker (ISSUE 9's
//! acceptance criteria): all three protocol models are exhaustively
//! explored through the public `analysis` API, the τ lost-update is
//! reproduced on the pre-fix publish protocol and ruled out on the
//! shipped one, and the whole exploration is deterministic — no clock,
//! no randomness, identical reports on every run.

use sdtw_repro::analysis::queue_model::QueueModel;
use sdtw_repro::analysis::reactor_model::ReactorModel;
use sdtw_repro::analysis::tau::TauModel;
use sdtw_repro::analysis::{Checker, ViolationKind};

/// The regression the tentpole exists for: the historical
/// `load(Relaxed)`-then-`store(Release)` τ publish loses an update
/// under a 2-thread interleaving the checker finds exhaustively, and
/// the `compare_exchange_weak` min-loop now in
/// `SharedThreshold::tighten` passes every schedule of the same
/// program.  If someone reverts the fix, the paired model (kept in
/// lock-step with the code by review + `docs/ANALYSIS.md`) keeps
/// documenting exactly which schedule breaks.
#[test]
fn tau_lost_update_reproduced_prefix_and_ruled_out_postfix() {
    let buggy = Checker::new(TauModel::buggy(100, &[30, 50])).run();
    let v = buggy.violation.expect(
        "the pre-fix load-then-store publish must lose an update in some schedule",
    );
    assert!(
        v.kind == ViolationKind::Invariant || v.kind == ViolationKind::Finale,
        "unexpected violation kind: {:?}",
        v.kind
    );
    assert!(!v.trace.is_empty(), "counterexample must carry a schedule");
    assert!(!buggy.depth_limited, "2-thread τ model must be fully explored");

    let fixed = Checker::new(TauModel::fixed(100, &[30, 50])).run();
    assert!(fixed.clean(), "CAS min-loop failed: {:?}", fixed.violation);

    // and with three contending shards
    let fixed3 = Checker::new(TauModel::fixed(100, &[30, 50, 70])).run();
    assert!(fixed3.clean(), "{:?}", fixed3.violation);
}

/// BoundedQueue push/pop/close: no lost or duplicated items, capacity
/// respected, FIFO per producer, and termination under every schedule
/// — including a closer racing both sides.  The missed-wakeup variant
/// (close without notify) must deadlock, proving the checker actually
/// discriminates.
#[test]
fn queue_protocol_verified_and_missed_wakeup_caught() {
    let clean = Checker::new(QueueModel::new(1, &[&[1, 2]], 1)).run();
    assert!(clean.clean(), "{:?}", clean.violation);
    assert!(clean.executions > 1, "close must race to distinct outcomes");

    let mpmc = Checker::new(QueueModel::new(2, &[&[1], &[2]], 2)).run();
    assert!(mpmc.clean(), "{:?}", mpmc.violation);

    let buggy = Checker::new(QueueModel::new(1, &[&[1]], 1).buggy_close()).run();
    let v = buggy.violation.expect("close-without-notify must deadlock");
    assert_eq!(v.kind, ViolationKind::Deadlock, "{}", v.message);
}

/// The reactor's per-connection Pending protocol: payload write before
/// the done flip, harvested in slot order — FIFO id-echo under every
/// executor completion order.  The inverted publish order must tear.
#[test]
fn reactor_fifo_verified_and_torn_publish_caught() {
    let clean = Checker::new(ReactorModel::new(3)).run();
    assert!(clean.clean(), "{:?}", clean.violation);

    let buggy = Checker::new(ReactorModel::buggy_done_first(2)).run();
    let v = buggy.violation.expect("done-before-payload must tear");
    assert_eq!(v.kind, ViolationKind::Invariant, "{}", v.message);
}

/// Determinism of the scheduler itself: identical reports — states,
/// transitions, executions, violation, trace — across repeated runs of
/// every model.  This is what makes a reported counterexample a
/// *reproducible* artifact rather than a flake.
#[test]
fn exploration_is_deterministic_across_runs() {
    for _ in 0..3 {
        assert_eq!(
            Checker::new(TauModel::buggy(100, &[30, 50])).run(),
            Checker::new(TauModel::buggy(100, &[30, 50])).run()
        );
        assert_eq!(
            Checker::new(QueueModel::new(1, &[&[1, 2]], 1)).run(),
            Checker::new(QueueModel::new(1, &[&[1, 2]], 1)).run()
        );
        assert_eq!(
            Checker::new(ReactorModel::new(2)).run(),
            Checker::new(ReactorModel::new(2)).run()
        );
    }
}

/// The state-space bounds documented in docs/ANALYSIS.md hold: the
/// models are small enough to explore exhaustively (no depth cutoff)
/// yet genuinely concurrent (hundreds of distinct configurations, not
/// a linear trace).
#[test]
fn models_are_exhaustive_within_documented_bounds() {
    for (name, report) in [
        ("tau2", Checker::new(TauModel::fixed(100, &[30, 50])).run()),
        ("tau3", Checker::new(TauModel::fixed(100, &[30, 50, 70])).run()),
        ("queue", Checker::new(QueueModel::new(1, &[&[1, 2]], 1)).run()),
        ("reactor", Checker::new(ReactorModel::new(3)).run()),
    ] {
        assert!(!report.depth_limited, "{name}: exploration was cut short");
        assert!(report.states > 10, "{name}: trivially small state space");
        assert!(
            report.states < 1_000_000,
            "{name}: state space exploded ({} states) — the docs' bounds \
             no longer hold",
            report.states
        );
        assert!(report.transitions >= report.states - 1, "{name}: not connected");
    }
}
