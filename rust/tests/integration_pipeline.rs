//! Integration: the full pipeline vs its composed parts, CLI-level
//! dataset round trips, and cross-implementation agreement (Rust oracle
//! vs scan formulation vs compiled artifacts) on realistic workloads.

use std::path::Path;

use sdtw_repro::datagen::{generate, io, Family, GenConfig};
use sdtw_repro::dtw::{self, sdtw_scan, Dist};
use sdtw_repro::normalize;
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::{Engine, HostTensor};

#[test]
fn pipeline_artifact_equals_znorm_then_sdtw() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let manifest = Manifest::load(Path::new("artifacts")).unwrap();
    let pipeline = manifest.require("pipeline_b8_m128_n2048_w16").unwrap().clone();
    let kernel = manifest.require("sdtw_b8_m128_n2048_w16").unwrap().clone();

    let ds = generate(&GenConfig {
        batch: 8,
        qlen: 128,
        reflen: 2048,
        seed: 21,
        family: Family::Ecg,
        ..Default::default()
    });
    let reference = normalize::znormed(&ds.reference);

    let engine = Engine::start(manifest).unwrap();
    let handle = engine.handle();

    // full pipeline on raw queries
    let out_pipe = handle
        .execute(
            &pipeline.name,
            vec![
                HostTensor::f32(&[8, 128], ds.queries.clone()).unwrap(),
                HostTensor::f32(&[2048], reference.clone()).unwrap(),
            ],
        )
        .unwrap();

    // manual composition: host znorm + sdtw kernel
    let mut qn = ds.queries.clone();
    normalize::znorm_batch(&mut qn, 128);
    let out_kern = handle
        .execute(
            &kernel.name,
            vec![
                HostTensor::f32(&[8, 128], qn.clone()).unwrap(),
                HostTensor::f32(&[2048], reference.clone()).unwrap(),
            ],
        )
        .unwrap();

    let a = out_pipe.outputs[0].as_f32().unwrap();
    let b = out_kern.outputs[0].as_f32().unwrap();
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= 1e-3 * y.abs().max(1.0),
            "q{i}: pipeline {x} vs composed {y}"
        );
    }

    // and both agree with the pure-Rust scan formulation
    for i in 0..8 {
        let q = &qn[i * 128..(i + 1) * 128];
        let want = sdtw_scan(q, &reference, 16, Dist::Sq);
        assert!(
            (a[i] - want.cost).abs() <= 1e-3 * want.cost.max(1.0),
            "q{i}: {x} vs rust-scan {w}",
            x = a[i],
            w = want.cost
        );
    }
}

#[test]
fn dataset_file_roundtrip_preserves_alignment_results() {
    let ds = generate(&GenConfig {
        batch: 4,
        qlen: 32,
        reflen: 256,
        seed: 31,
        family: Family::Walk,
        ..Default::default()
    });
    let dir = std::env::temp_dir().join("sdtw_pipeline_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ds.sdtw");
    io::write_dataset(&ds, &path).unwrap();
    let back = io::read_dataset(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let rn = normalize::znormed(&ds.reference);
    let rn2 = normalize::znormed(&back.reference);
    for i in 0..ds.batch() {
        let a = dtw::sdtw(&normalize::znormed(ds.query(i)), &rn, Dist::Sq);
        let b = dtw::sdtw(&normalize::znormed(back.query(i)), &rn2, Dist::Sq);
        assert_eq!(a, b, "q{i} changed across file round-trip");
    }
}

#[test]
fn cpu_batch_baseline_agrees_with_artifacts() {
    if !Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(Path::new("artifacts")).unwrap();
    let meta = manifest.require("sdtw_b8_m128_n2048_w16").unwrap().clone();
    let mut rng = sdtw_repro::util::rng::Xoshiro256::new(5);
    let mut queries = rng.normal_vec_f32(8 * 128);
    normalize::znorm_batch(&mut queries, 128);
    let reference = normalize::znormed(&rng.normal_vec_f32(2048));

    let cpu = dtw::sdtw_batch_cpu(&queries, 128, &reference, Dist::Sq, 2);

    let engine = Engine::start(manifest).unwrap();
    let out = engine
        .handle()
        .execute(
            &meta.name,
            vec![
                HostTensor::f32(&[8, 128], queries).unwrap(),
                HostTensor::f32(&[2048], reference).unwrap(),
            ],
        )
        .unwrap();
    let costs = out.outputs[0].as_f32().unwrap();
    let ends = out.outputs[1].as_i32().unwrap();
    for (i, m) in cpu.iter().enumerate() {
        assert!((costs[i] - m.cost).abs() <= 1e-4 * m.cost.max(1.0));
        assert_eq!(ends[i] as usize, m.end);
    }
}
