//! Capture the compiler identity at build time so bench artifacts can
//! record it (`bench_harness::emit_json` host-context fields) without a
//! runtime dependency on a toolchain being installed.

use std::process::Command;

fn main() {
    let rustc = std::env::var("RUSTC").unwrap_or_else(|_| "rustc".into());
    let version = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .unwrap_or_else(|| "unknown".into());
    println!("cargo:rustc-env=SDTW_RUSTC_VERSION={version}");
    println!("cargo:rerun-if-changed=build.rs");
}
