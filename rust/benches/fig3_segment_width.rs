//! Bench: paper Figure 3 — throughput vs segment (thread-coarsening)
//! width.  Paper: peak ≈ width 14, ~+30 % over width 2, degrading after.
//!
//!   cargo bench --bench fig3_segment_width

use sdtw_repro::bench_harness::banner;
use sdtw_repro::experiments::fig3_sweep;

fn main() -> anyhow::Result<()> {
    let protocol = banner("fig3", "sweep family from manifest");
    let table = fig3_sweep(std::path::Path::new("artifacts"), 42, protocol)?;
    table.print();

    let series: Vec<(u64, f64)> = table
        .rows
        .iter()
        .map(|r| {
            (
                r.cells[0].parse::<u64>().unwrap(),
                r.cells[1].parse::<f64>().unwrap(),
            )
        })
        .collect();
    let (wp, gp) = series
        .iter()
        .cloned()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();
    println!("peak width {wp} ({gp:.6} Gsps); paper peak ≈ 14 (+30% over width 2)");
    Ok(())
}
