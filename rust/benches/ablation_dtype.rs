//! Ablation: accumulator precision — f32 vs bf16 (TPU half) vs f16 (the
//! paper's __half2 fidelity).  Reports throughput AND accuracy deltas
//! against the f64 CPU oracle, which is the trade the paper's fp16
//! choice (and its §8 quantization plans) buys into.
//!
//!   cargo bench --bench ablation_dtype

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::experiments::{measure_variant, Workload};
use sdtw_repro::runtime::artifact::{Kind, Manifest};
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let protocol = banner("ablation_dtype", "f32 / bf16 / f16 at the serve shape");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Engine::start(manifest.clone())?;
    let handle = engine.handle();

    let variants = ["sdtw_b8_m128_n2048_w16", "sdtw_b8_m128_n2048_w16_bf16",
                    "sdtw_b8_m128_n2048_w16_f16"];
    let base = manifest.require(variants[0])?;
    let wl = Workload::for_variant(base, 42);

    // oracle costs for the accuracy column
    let oracle: Vec<f32> = (0..wl.b)
        .map(|i| {
            sdtw(&wl.queries_norm[i * wl.m..(i + 1) * wl.m], &wl.reference_norm, Dist::Sq)
                .cost
        })
        .collect();

    let mut table = Table::new(
        &format!("Dtype ablation (B={}, M={}, N={})", wl.b, wl.m, wl.n),
        &["dtype", "ms/batch", "Gcells/s", "max rel err"],
    );
    for name in variants {
        let meta = manifest.require(name)?;
        let s = measure_variant(&handle, meta, &wl, protocol)?;
        // one extra run for the accuracy column
        let out = handle.execute(name, wl.inputs_for(Kind::Sdtw))?;
        let costs = out.outputs[0].as_f32()?;
        let max_rel = costs
            .iter()
            .zip(&oracle)
            .map(|(c, o)| ((c - o) / o.max(1e-3)).abs())
            .fold(0f32, f32::max);
        table.row(
            name,
            vec![
                meta.dtype.clone(),
                format!("{:.2}", s.mean_ms),
                format!("{:.4}", s.gcups(wl.cells())),
                format!("{:.2e}", max_rel),
            ],
        );
    }
    table.print();
    println!("paper context: the HIP kernel computes entirely in __half2 fp16;");
    println!("bf16 is the TPU-native equivalent (DESIGN.md §1).");
    Ok(())
}
