//! Ablation: Discussion-§8 uint8 codebook quantization — throughput and
//! accuracy of the quantized pipeline vs the exact pipeline.
//!
//!   cargo bench --bench ablation_quant

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::dtw::{sdtw, Dist};
use sdtw_repro::experiments::{measure_variant, Workload};
use sdtw_repro::quant::Codebook;
use sdtw_repro::runtime::artifact::{Kind, Manifest};
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let protocol = banner("ablation_quant", "exact vs uint8-codebook pipeline");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Engine::start(manifest.clone())?;
    let handle = engine.handle();

    let exact = manifest.require("pipeline_b8_m128_n2048_w16")?;
    let quant = manifest.require("pipeline_b8_m128_n2048_w16_quant")?;
    let wl = Workload::for_variant(exact, 42);

    let oracle: Vec<f32> = (0..wl.b)
        .map(|i| {
            sdtw(&wl.queries_norm[i * wl.m..(i + 1) * wl.m], &wl.reference_norm, Dist::Sq)
                .cost
        })
        .collect();

    let mut table = Table::new(
        &format!("Quantization ablation (B={}, M={}, N={})", wl.b, wl.m, wl.n),
        &["ms/batch", "max rel err vs oracle"],
    );
    for (label, meta) in [("exact f32 pipeline", exact), ("uint8 codebook pipeline", quant)] {
        let s = measure_variant(&handle, meta, &wl, protocol)?;
        let out = handle.execute(&meta.name, wl.inputs_for(Kind::Pipeline))?;
        let costs = out.outputs[0].as_f32()?;
        let max_rel = costs
            .iter()
            .zip(&oracle)
            .map(|(c, o)| ((c - o) / o.max(1e-3)).abs())
            .fold(0f32, f32::max);
        table.row(
            label,
            vec![format!("{:.2}", s.mean_ms), format!("{:.2e}", max_rel)],
        );
    }
    table.print();

    // CPU-side codec error analysis (the §8 design numbers)
    let cb = Codebook::from_series(&wl.reference_norm, 4.0);
    println!(
        "codebook [{:.3}, {:.3}] step {:.5}; max in-range reconstruction error {:.5}",
        cb.lo,
        cb.hi,
        cb.step(),
        cb.max_inrange_error(&wl.reference_norm)
    );
    Ok(())
}
