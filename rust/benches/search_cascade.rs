//! Ablation: the top-K search cascade, stage by stage — brute force
//! (every window costed) vs LB_Kim only, LB_Kim+LB_Keogh, and the full
//! cascade with DP early abandoning.  Reports per-stage prune rates and
//! verifies on every shape that the cascade's top-K is bit-identical to
//! brute force (pruning is lossless by construction).
//!
//!   cargo bench --bench search_cascade
//!   SDTW_BENCH_QUICK=1 cargo bench --bench search_cascade   # fast run
//!
//! Workloads are the datagen families the paper's generator motivates:
//! a drifting random walk (level changes make the envelope bounds bite)
//! and Cylinder-Bell-Funnel (flat-ish: pruning must come from the DP
//! abandon stage) — each with planted, warped, noisy copies of the query
//! so the heap threshold has genuine matches to lock onto.

use std::sync::Arc;

use sdtw_repro::bench_harness::{banner, emit_json, Table};
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::Dist;
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{CascadeOpts, CascadeStats, SearchEngine};
use sdtw_repro::util::json::Json;
use sdtw_repro::util::rng::Xoshiro256;

const REFLEN: usize = 8192;
const QLEN: usize = 128;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 6;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 6;

fn workload(family: Family, seed: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(family, REFLEN, QLEN, PLANTS, 0.05, &mut rng);
    (Arc::new(znormed(&reference)), znormed(&query))
}

fn main() -> anyhow::Result<()> {
    let protocol = banner(
        "search_cascade",
        &format!("N={REFLEN} M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION}"),
    );

    let stages: [(&str, CascadeOpts); 4] = [
        ("brute force (no cascade)", CascadeOpts::BRUTE),
        (
            "LB_Kim only",
            CascadeOpts { kim: true, keogh: false, abandon: false, ..CascadeOpts::BRUTE },
        ),
        (
            "LB_Kim + LB_Keogh",
            CascadeOpts { kim: true, keogh: true, abandon: false, ..CascadeOpts::BRUTE },
        ),
        ("full cascade (+DP abandon)", CascadeOpts::default()),
    ];

    for family in [Family::Walk, Family::Cbf] {
        let (reference, query) = workload(family, 42);
        let engine = SearchEngine::new(reference, WINDOW, 1, Dist::Sq)?;
        let candidates = engine.index().candidates();

        // correctness first: every stage combination must reproduce the
        // brute-force top-K bit-for-bit
        let brute = engine.search_opts(&query, K, EXCLUSION, CascadeOpts::BRUTE, 1)?;
        for (label, opts) in &stages {
            let got = engine.search_opts(&query, K, EXCLUSION, *opts, 1)?;
            assert_eq!(got.hits, brute.hits, "{label} diverged from brute force");
        }

        let mut table = Table::new(
            &format!("Cascade ablation — {family:?} ({candidates} candidate windows)"),
            &["ms/search", "speedup", "kim%", "keogh%", "abandon%", "pruned%"],
        );
        let mut brute_ms = 0.0f64;
        for (label, opts) in &stages {
            let mut stats = CascadeStats::default();
            let summary = protocol.run(|| {
                stats = engine
                    .search_opts(&query, K, EXCLUSION, *opts, 1)
                    .expect("search")
                    .stats;
            });
            if brute_ms == 0.0 {
                brute_ms = summary.mean_ms;
            }
            let pct = |x: u64| 100.0 * x as f64 / stats.candidates.max(1) as f64;
            table.row(
                label,
                vec![
                    format!("{:.2}", summary.mean_ms),
                    format!("{:.1}x", brute_ms / summary.mean_ms.max(1e-9)),
                    format!("{:.1}", pct(stats.pruned_kim)),
                    format!("{:.1}", pct(stats.pruned_keogh)),
                    format!("{:.1}", pct(stats.dp_abandoned)),
                    format!("{:.1}", stats.prune_fraction() * 100.0),
                ],
            );
            emit_json(
                "search_cascade",
                vec![
                    ("family", Json::str(&format!("{family:?}"))),
                    ("config", Json::str(label)),
                    ("candidates", Json::Int(candidates as i64)),
                    ("ms_per_search", Json::Num(summary.mean_ms)),
                    ("speedup_vs_brute", Json::Num(brute_ms / summary.mean_ms.max(1e-9))),
                    ("prune_fraction", Json::Num(stats.prune_fraction())),
                    ("pruned_kim", Json::Int(stats.pruned_kim as i64)),
                    ("pruned_keogh", Json::Int(stats.pruned_keogh as i64)),
                    ("dp_abandoned", Json::Int(stats.dp_abandoned as i64)),
                    ("dp_full", Json::Int(stats.dp_full as i64)),
                    ("survivors", Json::Int(stats.survivors() as i64)),
                    ("survivor_batches", Json::Int(stats.survivor_batches as i64)),
                    ("bit_identical", Json::Bool(true)),
                ],
            );
        }
        table.print();

        let full = engine.search_opts(&query, K, EXCLUSION, CascadeOpts::default(), 1)?;
        let pruned = full.stats.prune_fraction() * 100.0;
        println!(
            "{family:?}: full cascade pruned {pruned:.1}% of {candidates} windows \
             (acceptance target: >= 50%){}",
            if pruned >= 50.0 { " ✓" } else { "  ** BELOW TARGET **" }
        );
        println!(
            "{family:?}: prune→survivor→batch ratio: {candidates} candidates → {} \
             survivors → {} kernel batches (lane occupancy {:.2}; see \
             benches/survivor_batch.rs for the lane-kernel ablation)",
            full.stats.survivors(),
            full.stats.survivor_batches,
            full.stats.mean_lane_occupancy()
        );
    }
    println!(
        "\nnote: per-stage counters also stream into MetricsSnapshot \
         (searches/windows/pruned_*) when searches are served through the \
         coordinator — see `sdtw search` and the `search` protocol verb."
    );
    Ok(())
}
