//! Append+search throughput: the streaming index (incremental Lemire
//! envelopes + delta search) against the full-rebuild baseline
//! (`ReferenceIndex::build` on every grown prefix + a from-scratch
//! cascade), on a growing read-until style stream.
//!
//!   cargo bench --bench streaming_append
//!   SDTW_BENCH_QUICK=1 cargo bench --bench streaming_append  # fast run
//!
//! Reading the table: the rebuild row pays O(prefix) envelope sweeps
//! per chunk and re-cascades every candidate every search, so its cost
//! per chunk grows with the stream; the streaming row pays O(chunk)
//! appends and cascades only the delta (plus the cached-τ merge), so
//! its cost per chunk stays flat.  `cascaded` counts candidate windows
//! the search pass actually walked — the incremental-vs-rebuild work
//! ratio.  Bit-identity of the top-K at every step is the gate before
//! anything is timed as a result.

use std::sync::Arc;
use std::time::Instant;

use sdtw_repro::bench_harness::Table;
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::Dist;
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{CascadeOpts, SearchEngine, StreamingEngine};
use sdtw_repro::util::rng::Xoshiro256;

const QLEN: usize = 96;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 5;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 8;

fn shape() -> (usize, usize, usize) {
    // (total stream, warmup prefix, samples per append)
    if std::env::var("SDTW_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
        (32_768, 8_192, 2_048)
    } else {
        (131_072, 16_384, 4_096)
    }
}

fn workload(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(Family::Walk, n, QLEN, PLANTS, 0.05, &mut rng);
    (znormed(&reference), znormed(&query))
}

fn main() -> anyhow::Result<()> {
    let (n, warmup, chunk) = shape();
    let chunks = (n - warmup).div_ceil(chunk);
    println!(
        "[streaming_append] stream N={n} (warmup {warmup}, {chunks} appends of {chunk}) \
         M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION}"
    );

    let (reference, query) = workload(n, 42);
    let opts = CascadeOpts::default();

    // ---- streaming: incremental index + delta search per chunk
    let t0 = Instant::now();
    let mut engine = StreamingEngine::new(&reference[..warmup], WINDOW, 1, Dist::Sq)?;
    let mut stream_hits = Vec::with_capacity(chunks);
    let mut stream_cascaded = 0u64;
    let mut at = warmup;
    while at < n {
        let end = (at + chunk).min(n);
        engine.append(&reference[at..end]);
        at = end;
        let d = engine.search_delta(&query, K, EXCLUSION, opts)?;
        stream_cascaded += d.scanned;
        stream_hits.push(d.outcome.hits);
    }
    let stream_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ---- baseline: rebuild the batch index on every prefix + full search
    let t1 = Instant::now();
    let mut rebuild_hits = Vec::with_capacity(chunks);
    let mut rebuild_cascaded = 0u64;
    let mut at = warmup;
    while at < n {
        let end = (at + chunk).min(n);
        at = end;
        let batch = SearchEngine::new(
            Arc::new(reference[..at].to_vec()),
            WINDOW,
            1,
            Dist::Sq,
        )?;
        let out = batch.search_opts(&query, K, EXCLUSION, opts, 1)?;
        rebuild_cascaded += out.stats.candidates;
        rebuild_hits.push(out.hits);
    }
    let rebuild_ms = t1.elapsed().as_secs_f64() * 1e3;

    // correctness gate: bit-identical top-K after every single append
    assert_eq!(stream_hits.len(), rebuild_hits.len());
    for (i, (s, r)) in stream_hits.iter().zip(&rebuild_hits).enumerate() {
        assert_eq!(
            s, r,
            "append {i}: streaming top-K diverged from the rebuild baseline"
        );
    }

    let mut table = Table::new(
        &format!("Streaming append+search vs full rebuild — Walk ({chunks} appends)"),
        &["total ms", "ms/append", "cascaded", "speedup"],
    );
    table.row(
        "rebuild + full search",
        vec![
            format!("{rebuild_ms:.1}"),
            format!("{:.2}", rebuild_ms / chunks as f64),
            format!("{rebuild_cascaded}"),
            "1.00x".to_string(),
        ],
    );
    table.row(
        "streaming append + delta search",
        vec![
            format!("{stream_ms:.1}"),
            format!("{:.2}", stream_ms / chunks as f64),
            format!("{stream_cascaded}"),
            format!("{:.2}x", rebuild_ms / stream_ms.max(1e-9)),
        ],
    );
    table.print();
    println!(
        "(cascaded = candidate windows the search pass walked; the delta path re-walks \
         only what each append added — results verified bit-identical per append)"
    );
    Ok(())
}
