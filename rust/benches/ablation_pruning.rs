//! Ablation: Discussion-§8 early pruning (INF tiles).  Measures the
//! pruned kernel vs exact at the serve shape, the fraction of cells the
//! CPU oracle says are prunable at the chosen threshold, and verifies
//! pruning preserves genuine matches.
//!
//!   cargo bench --bench ablation_pruning

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::dtw::pruned::sdtw_pruned;
use sdtw_repro::dtw::Dist;
use sdtw_repro::experiments::{measure_variant, Workload};
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let protocol = banner("ablation_pruning", "exact vs INF-tile pruned kernel");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Engine::start(manifest.clone())?;
    let handle = engine.handle();

    let exact = manifest.require("sdtw_b8_m128_n2048_w16")?;
    let pruned = manifest.require("sdtw_b8_m128_n2048_w16_pruned")?;
    let threshold = pruned.prune_threshold.unwrap_or(4.0) as f32;
    let wl = Workload::for_variant(exact, 42);

    // CPU-side pruning effectiveness at this threshold
    let mut prunable = 0u64;
    let mut total = 0u64;
    for i in 0..wl.b {
        let p = sdtw_pruned(
            &wl.queries_norm[i * wl.m..(i + 1) * wl.m],
            &wl.reference_norm,
            threshold,
            Dist::Sq,
        );
        prunable += p.pruned_cells;
        total += p.total_cells;
    }

    let mut table = Table::new(
        &format!(
            "Pruning ablation (threshold {threshold}; {:.1}% of cells prunable)",
            prunable as f64 / total as f64 * 100.0
        ),
        &["ms/batch", "Gcells/s"],
    );
    for (label, meta) in [("exact", exact), ("pruned (INF tiles)", pruned)] {
        let s = measure_variant(&handle, meta, &wl, protocol)?;
        table.row(
            label,
            vec![format!("{:.2}", s.mean_ms), format!("{:.4}", s.gcups(wl.cells()))],
        );
    }
    table.print();
    println!(
        "note: on vector hardware INF tiles skip no lanes — the win the paper\n\
         anticipates needs divergence-free masking or sparsity, which is why the\n\
         measured delta is ~neutral here; the CPU baseline (dtw::pruned) shows the\n\
         {:.0}% work reduction an implementation could exploit.",
        prunable as f64 / total as f64 * 100.0
    );
    Ok(())
}
