//! Ablation: dynamic-batching policy — how the coordinator's deadline
//! knob trades latency against padding waste and throughput under an
//! open-loop arrival process.  This is the L3 counterpart of the paper's
//! fixed-batch design (the kernel always runs full 512-query batches;
//! the cost of *filling* those batches is the serving system's problem).
//!
//!   cargo bench --bench ablation_batching

use std::sync::Arc;
use std::time::{Duration, Instant};

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::coordinator::{AlignOptions, SdtwService, ServiceOptions};
use sdtw_repro::datagen::{generate, Family, GenConfig};
use sdtw_repro::util::stats::percentile;

const VARIANT: &str = "pipeline_b8_m128_n2048_w16";

fn main() -> anyhow::Result<()> {
    let _ = banner("ablation_batching", "deadline sweep under open-loop load");
    let ds = Arc::new(generate(&GenConfig {
        batch: 64,
        qlen: 128,
        reflen: 2048,
        seed: 5,
        family: Family::Ecg,
        ..Default::default()
    }));

    // arrival rate is tuned below service capacity so the deadline knob
    // is the binding constraint (saturated queues always fill batches)
    let mut table = Table::new(
        "Batching-policy ablation (3 paced clients, 60 req each, ~6ms spacing)",
        &["deadline ms", "p50 ms", "p99 ms", "rows/batch", "padding %"],
    );
    for deadline_ms in [0.5f64, 2.0, 5.0, 20.0] {
        let service = Arc::new(SdtwService::start(
            ServiceOptions {
                variant: VARIANT.into(),
                workers: 2,
                batch_deadline: Duration::from_secs_f64(deadline_ms / 1e3),
                ..Default::default()
            },
            ds.reference.clone(),
        )?);
        let mut handles = Vec::new();
        for c in 0..3 {
            let service = service.clone();
            let ds = ds.clone();
            handles.push(std::thread::spawn(move || -> Vec<f64> {
                let mut lat = Vec::new();
                for k in 0..60 {
                    let q = ds.query((c * 13 + k * 3) % ds.batch()).to_vec();
                    let t = Instant::now();
                    if service.align_blocking(q, AlignOptions::default()).is_ok() {
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                    }
                    std::thread::sleep(Duration::from_millis(6));
                }
                lat
            }));
        }
        let lat: Vec<f64> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        let m = service.metrics();
        table.row(
            &format!("{deadline_ms}"),
            vec![
                format!("{deadline_ms}"),
                format!("{:.2}", percentile(&lat, 50.0)),
                format!("{:.2}", percentile(&lat, 99.0)),
                format!("{:.2}", m.real_rows as f64 / m.batches.max(1) as f64),
                format!("{:.1}", m.padding_fraction() * 100.0),
            ],
        );
    }
    table.print();
    println!("with closed-loop clients the deadline is pure added latency once the");
    println!("in-flight population is batched (rows/batch = #clients): the knob only");
    println!("fills batches further when arrivals outpace service. The paper's fixed");
    println!("512-batch sits at the far end: maximal fill, unbounded queueing delay.");
    Ok(())
}
