//! Ablation: local-scan implementation × segment width, on the deployed
//! backend (xla_extension 0.5.1 CPU).  This is the measurement behind
//! the kernel's DEFAULT_SCAN_IMPL choice — see EXPERIMENTS.md §Perf.
//!
//!   cargo bench --bench ablation_scan

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::experiments::{measure_variant, Workload};
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let protocol = banner("ablation_scan", "scan impl x width matrix");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let engine = Engine::start(manifest.clone())?;
    let handle = engine.handle();

    let mut family: Vec<_> = manifest
        .variants
        .iter()
        .filter(|v| v.ablation.as_deref() == Some("scan"))
        .collect();
    anyhow::ensure!(!family.is_empty(), "no scan-ablation variants; re-run make artifacts");
    family.sort_by_key(|v| (v.scan_impl.clone(), v.segment_width));

    let wl = Workload::for_variant(family[0], 42);
    let mut table = Table::new(
        &format!("Scan-impl ablation (B={}, M={}, N={})", wl.b, wl.m, wl.n),
        &["impl", "width", "ms/batch", "Gcells/s"],
    );
    for meta in family {
        let s = measure_variant(&handle, meta, &wl, protocol)?;
        table.row(
            &meta.name,
            vec![
                meta.scan_impl.clone().unwrap_or_default(),
                format!("{}", meta.segment_width.unwrap_or(0)),
                format!("{:.2}", s.mean_ms),
                format!("{:.4}", s.gcups(wl.cells())),
            ],
        );
    }
    table.print();
    Ok(())
}
