//! Ablation: the batched lower-bound prefilter kernel — the scalar
//! per-candidate cadence vs the SoA block kernel at widths 1..64.
//! Reports per-config wall time, candidate throughput, LB block count /
//! occupancy / Keogh-abandon counts, and verifies on every shape that
//! each configuration's top-K is bit-identical to the scalar-prefilter
//! engine (batching the bounds is lossless by construction — the
//! cascade's τ-refresh argument).
//!
//!   cargo bench --bench lb_prefilter
//!   SDTW_BENCH_QUICK=1 cargo bench --bench lb_prefilter       # fast run
//!   SDTW_BENCH_JSON=out.jsonl ... cargo bench --bench lb_prefilter
//!       # also append machine-readable summaries (the CI bench-smoke
//!       # lane's BENCH_ci.json feed)
//!
//! Workloads are the same planted families as `search_cascade`: a
//! drifting walk (envelope bounds bite, most candidates die in the LB
//! stages — the block kernel's best case) and Cylinder-Bell-Funnel
//! (flat-ish, Keogh abandons carry more of the work).

use std::sync::Arc;

use sdtw_repro::bench_harness::{banner, emit_json, Table};
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::Dist;
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{CascadeOpts, CascadeStats, LbKernelSpec, SearchEngine};
use sdtw_repro::util::json::Json;
use sdtw_repro::util::rng::Xoshiro256;

const REFLEN: usize = 8192;
const QLEN: usize = 128;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 6;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 6;
const SEED: u64 = 42;

fn workload(family: Family, seed: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(family, REFLEN, QLEN, PLANTS, 0.05, &mut rng);
    (Arc::new(znormed(&reference)), znormed(&query))
}

fn main() -> anyhow::Result<()> {
    let protocol = banner(
        "lb_prefilter",
        &format!("N={REFLEN} M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION} seed={SEED}"),
    );

    let configs: [(&str, LbKernelSpec); 5] = [
        ("scalar prefilter", LbKernelSpec::SCALAR),
        ("block B=1", LbKernelSpec::block(1)),
        ("block B=8", LbKernelSpec::block(8)),
        ("block B=32", LbKernelSpec::block(32)),
        ("block B=64", LbKernelSpec::block(64)),
    ];

    for family in [Family::Walk, Family::Cbf] {
        let (reference, query) = workload(family, SEED);
        let engine = SearchEngine::new(reference, WINDOW, 1, Dist::Sq)?;
        let candidates = engine.index().candidates();

        // correctness first: every prefilter configuration must
        // reproduce the scalar engine's top-K bit-for-bit (which the
        // search_cascade bench in turn gates against brute force)
        let base = engine.search_opts(&query, K, EXCLUSION, CascadeOpts::default(), 1)?;
        for (label, spec) in &configs {
            let opts = CascadeOpts::default().with_lb(*spec);
            let got = engine.search_opts(&query, K, EXCLUSION, opts, 1)?;
            assert_eq!(got.hits.len(), base.hits.len(), "{label}: hit count diverged");
            for (a, b) in got.hits.iter().zip(&base.hits) {
                assert_eq!(a.start, b.start, "{label}: start diverged");
                assert_eq!(a.end, b.end, "{label}: end diverged");
                assert_eq!(
                    a.cost.to_bits(),
                    b.cost.to_bits(),
                    "{label}: cost not bit-identical ({} vs {})",
                    a.cost,
                    b.cost
                );
            }
            let s = got.stats;
            assert_eq!(
                s.pruned_total() + s.dp_full,
                s.candidates,
                "{label}: counters must partition the candidate space"
            );
        }

        let mut table = Table::new(
            &format!("LB prefilter ablation — {family:?} ({candidates} candidate windows)"),
            &["ms/search", "Mcand/s", "speedup", "pruned%", "lb_blocks", "occup", "abandons"],
        );
        let mut scalar_ms = 0.0f64;
        for (label, spec) in &configs {
            let opts = CascadeOpts::default().with_lb(*spec);
            let mut stats = CascadeStats::default();
            let summary = protocol.run(|| {
                stats = engine
                    .search_opts(&query, K, EXCLUSION, opts, 1)
                    .expect("search")
                    .stats;
            });
            if scalar_ms == 0.0 {
                scalar_ms = summary.mean_ms;
            }
            let mcand_s = candidates as f64 / (summary.mean_ms * 1e3).max(1e-12);
            table.row(
                label,
                vec![
                    format!("{:.3}", summary.mean_ms),
                    format!("{:.2}", mcand_s),
                    format!("{:.2}x", scalar_ms / summary.mean_ms.max(1e-9)),
                    format!("{:.1}", stats.prune_fraction() * 100.0),
                    format!("{}", stats.lb_blocks),
                    format!("{:.1}", stats.mean_lb_block_occupancy()),
                    format!("{}", stats.lb_abandons),
                ],
            );
            emit_json(
                "lb_prefilter",
                vec![
                    ("family", Json::str(&format!("{family:?}"))),
                    ("config", Json::str(label)),
                    ("candidates", Json::Int(candidates as i64)),
                    ("ms_per_search", Json::Num(summary.mean_ms)),
                    ("mcand_per_s", Json::Num(mcand_s)),
                    ("prune_fraction", Json::Num(stats.prune_fraction())),
                    ("pruned_kim", Json::Int(stats.pruned_kim as i64)),
                    ("pruned_keogh", Json::Int(stats.pruned_keogh as i64)),
                    ("dp_abandoned", Json::Int(stats.dp_abandoned as i64)),
                    ("dp_full", Json::Int(stats.dp_full as i64)),
                    ("survivors", Json::Int(stats.survivors() as i64)),
                    ("lb_blocks", Json::Int(stats.lb_blocks as i64)),
                    ("lb_occupancy", Json::Num(stats.mean_lb_block_occupancy())),
                    ("lb_abandons", Json::Int(stats.lb_abandons as i64)),
                    ("bit_identical", Json::Bool(true)),
                ],
            );
        }
        table.print();
    }
    println!(
        "\nnote: every configuration above was asserted bit-identical to the \
         scalar-prefilter top-K before timing; `sdtw search --lb-kernel block \
         --lb-block N` serves the same configurations end-to-end."
    );
    Ok(())
}
