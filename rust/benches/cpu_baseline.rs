//! Bench: the CPU baseline the paper frames against ("producing these
//! expected outputs on the CPU is a time-consuming process", §4).
//! Single-thread and all-core multithreaded sDTW over the same batch the
//! compiled kernel serves, so EXPERIMENTS.md can report the
//! device-vs-CPU ratio on identical work.
//!
//!   cargo bench --bench cpu_baseline

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::dtw::batch::{default_threads, sdtw_batch_cpu};
use sdtw_repro::dtw::Dist;
use sdtw_repro::experiments::{measure_variant, Workload};
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let protocol = banner("cpu_baseline", "CPU oracle vs compiled kernel, same batch");
    let manifest = Manifest::load(std::path::Path::new("artifacts"))?;
    let meta = manifest.require("sdtw_b32_m256_n4096_w16")?;
    let wl = Workload::for_variant(meta, 42);

    let mut table = Table::new(
        &format!("CPU baseline vs XLA kernel (B={}, M={}, N={})", wl.b, wl.m, wl.n),
        &["ms/batch", "Gcells/s"],
    );

    let s1 = protocol.run(|| {
        sdtw_batch_cpu(&wl.queries_norm, wl.m, &wl.reference_norm, Dist::Sq, 1);
    });
    table.row(
        "CPU sequential (1 thread)",
        vec![format!("{:.1}", s1.mean_ms), format!("{:.4}", s1.gcups(wl.cells()))],
    );

    let nt = default_threads();
    let sn = protocol.run(|| {
        sdtw_batch_cpu(&wl.queries_norm, wl.m, &wl.reference_norm, Dist::Sq, nt);
    });
    table.row(
        &format!("CPU parallel ({nt} threads)"),
        vec![format!("{:.1}", sn.mean_ms), format!("{:.4}", sn.gcups(wl.cells()))],
    );

    let engine = Engine::start(manifest.clone())?;
    let sx = measure_variant(&engine.handle(), meta, &wl, protocol)?;
    table.row(
        "XLA compiled kernel",
        vec![format!("{:.1}", sx.mean_ms), format!("{:.4}", sx.gcups(wl.cells()))],
    );
    table.print();
    println!(
        "speedup vs 1-thread CPU: kernel {:.1}x, {}-thread CPU {:.1}x",
        s1.mean_ms / sx.mean_ms,
        nt,
        s1.mean_ms / sn.mean_ms
    );
    Ok(())
}
