//! Ablation: scalar per-window survivor DP vs the lane-batched lockstep
//! kernel, over lane counts {1, 4, 8, 16} — the CPU realization of the
//! paper's thread-coarsening sweep (Fig. 3), applied to the cascade's
//! "batched DP for survivors" stage.
//!
//!   cargo bench --bench survivor_batch
//!   SDTW_BENCH_QUICK=1 cargo bench --bench survivor_batch   # fast run
//!
//! Part 1 times the raw kernels on fixed survivor sets (the same
//! windows, bit-identity asserted first), so the lane win is isolated
//! from cascade noise: the scalar DP is a sequential min-chain along the
//! reference, while the lane kernel advances L independent cells per
//! step — the chain's latency amortizes over the lanes.  The acceptance
//! target is the lane kernel beating per-window scalar DP on survivor
//! batches of >= 8 windows.
//!
//! Part 2 runs the full cascade end-to-end per kernel, reporting
//! survivor counts, kernel batches, and lane occupancy alongside wall
//! time (the same counters `MetricsSnapshot` serves in production).

use std::sync::Arc;

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::kernel::{DpKernel, KernelSpec, Lane};
use sdtw_repro::dtw::Dist;
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{CascadeOpts, SearchEngine};
use sdtw_repro::util::rng::Xoshiro256;

const QLEN: usize = 128;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 6;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 8;

fn reflen() -> usize {
    if std::env::var("SDTW_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
        16_384
    } else {
        65_536
    }
}

fn workload(n: usize, seed: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(Family::Walk, n, QLEN, PLANTS, 0.05, &mut rng);
    (Arc::new(znormed(&reference)), znormed(&query))
}

fn main() -> anyhow::Result<()> {
    let n = reflen();
    let protocol = banner(
        "survivor_batch",
        &format!("N={n} M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION}"),
    );
    let (reference, query) = workload(n, 42);
    let engine = SearchEngine::new(reference, WINDOW, 1, Dist::Sq)?;
    let candidates = engine.index().candidates();

    // ---- part 1: raw kernel ablation on fixed survivor sets ----------
    let specs: [(&str, KernelSpec); 5] = [
        ("scalar (per-window DP)", KernelSpec::SCALAR),
        ("lanes x 1", KernelSpec::lanes(1)),
        ("lanes x 4", KernelSpec::lanes(4)),
        ("lanes x 8", KernelSpec::lanes(8)),
        ("lanes x 16", KernelSpec::lanes(16)),
    ];

    for survivors in [8usize, 64] {
        // a fixed, reproducible survivor set: candidates spread evenly
        // across the index (planted sites land inside it by layout)
        let ids: Vec<usize> = (0..survivors)
            .map(|i| (i * candidates) / survivors)
            .collect();
        let lanes: Vec<Lane<'_>> = ids
            .iter()
            .map(|&t| Lane { query: &query, window: engine.index().window_slice(t) })
            .collect();

        // correctness gate before timing anything: every kernel must be
        // bit-identical to the scalar referee on every lane
        let mut referee = KernelSpec::SCALAR.instantiate();
        let mut want = Vec::new();
        referee.run(&lanes, f32::INFINITY, Dist::Sq, &mut want);
        for (_, spec) in &specs {
            let mut out = Vec::new();
            spec.instantiate().run(&lanes, f32::INFINITY, Dist::Sq, &mut out);
            for (i, (a, b)) in out.iter().zip(&want).enumerate() {
                let (a, b) = (a.unwrap(), b.unwrap());
                assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{spec:?} lane {i}");
                assert_eq!(a.end, b.end, "{spec:?} lane {i}");
            }
        }

        let cells = (survivors * QLEN * WINDOW) as f64;
        let mut table = Table::new(
            &format!("Survivor-batch DP — {survivors} surviving windows of {WINDOW}"),
            &["ms/batch", "speedup", "Mcells/s"],
        );
        let mut scalar_ms = 0.0f64;
        for (label, spec) in &specs {
            let mut kernel = spec.instantiate();
            let mut out = Vec::new();
            let summary = protocol.run(|| {
                kernel.run(&lanes, f32::INFINITY, Dist::Sq, &mut out);
                assert_eq!(out.len(), lanes.len());
            });
            if scalar_ms == 0.0 {
                scalar_ms = summary.mean_ms;
            }
            table.row(
                label,
                vec![
                    format!("{:.3}", summary.mean_ms),
                    format!("{:.2}x", scalar_ms / summary.mean_ms.max(1e-9)),
                    format!("{:.1}", cells / (summary.mean_ms.max(1e-9) * 1e3)),
                ],
            );
        }
        table.print();
    }

    // ---- part 2: the cascade end-to-end per kernel -------------------
    let serial = engine.search_opts(&query, K, EXCLUSION, CascadeOpts::default(), 1)?;
    let mut table = Table::new(
        &format!("End-to-end cascade by survivor kernel — Walk ({candidates} candidates)"),
        &["ms/search", "speedup", "survivors", "batches", "occupancy"],
    );
    let mut scalar_ms = 0.0f64;
    for (label, spec) in &specs {
        let opts = CascadeOpts::default().with_kernel(*spec);
        let out = engine.search_opts(&query, K, EXCLUSION, opts, 1)?;
        assert_eq!(out.hits, serial.hits, "{label} diverged from the scalar cascade");
        let mut stats = out.stats;
        let summary = protocol.run(|| {
            stats = engine
                .search_opts(&query, K, EXCLUSION, opts, 1)
                .expect("search")
                .stats;
        });
        if scalar_ms == 0.0 {
            scalar_ms = summary.mean_ms;
        }
        table.row(
            label,
            vec![
                format!("{:.2}", summary.mean_ms),
                format!("{:.2}x", scalar_ms / summary.mean_ms.max(1e-9)),
                format!("{}", stats.survivors()),
                format!("{}", stats.survivor_batches),
                format!("{:.2}", stats.mean_lane_occupancy()),
            ],
        );
    }
    table.print();
    println!(
        "(speedup vs the scalar kernel; occupancy = mean windows per kernel batch — \
         1.0 means survivors arrived one at a time, the lane count means every \
         batch filled.  End-to-end gains track occupancy: heavy pruning starves \
         the lane kernel, weak pruning feeds it.)"
    );
    Ok(())
}
