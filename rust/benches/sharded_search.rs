//! Scaling sweep: the sharded parallel search executor at 1/2/4/8 shards
//! (worker threads = shards) against the serial cascade on a large
//! planted reference.  Verifies on every configuration that the sharded
//! top-K is bit-identical to the serial engine, then reports wall time,
//! speedup, shard imbalance, and how often the shared prune threshold
//! tightened (the cross-shard pruning win).
//!
//!   cargo bench --bench sharded_search
//!   SDTW_BENCH_QUICK=1 cargo bench --bench sharded_search   # fast run
//!
//! Reading the table: ideal scaling halves ms/search per doubling of
//! shards; the gap to ideal is explained by (a) imbalance — pruning makes
//! shard cost data-dependent — and (b) the serial sort + merge tail.
//! `tighten` counts shared-τ decreases: a low number at high shard
//! counts means shards mostly pruned off their own early candidates,
//! a high number means cross-shard tightening carried the cascade.

use std::sync::Arc;

use sdtw_repro::bench_harness::{banner, Table};
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::Dist;
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{CascadeOpts, SearchEngine, ShardedOutcome};
use sdtw_repro::util::rng::Xoshiro256;

const QLEN: usize = 128;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 8;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 12;

fn reflen() -> usize {
    // quick: still large enough that shard scheduling overhead is noise
    if std::env::var("SDTW_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
        32_768
    } else {
        131_072
    }
}

fn workload(n: usize, seed: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(Family::Walk, n, QLEN, PLANTS, 0.05, &mut rng);
    (Arc::new(znormed(&reference)), znormed(&query))
}

fn main() -> anyhow::Result<()> {
    let n = reflen();
    let protocol = banner(
        "sharded_search",
        &format!("N={n} M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION}"),
    );

    let (reference, query) = workload(n, 42);
    let engine = SearchEngine::new(reference, WINDOW, 1, Dist::Sq)?;
    let candidates = engine.index().candidates();

    // correctness gate: every shard/thread configuration must reproduce
    // the serial engine's top-K bit-for-bit before we time anything
    let serial = engine.search(&query, K, EXCLUSION)?;
    for shards in [1usize, 2, 4, 8] {
        let out = engine.search_sharded(
            &query,
            K,
            EXCLUSION,
            CascadeOpts::default(),
            shards,
            shards,
        )?;
        assert_eq!(
            out.hits, serial.hits,
            "{shards}-shard executor diverged from the serial engine"
        );
    }

    let mut table = Table::new(
        &format!("Sharded search scaling — Walk ({candidates} candidate windows)"),
        &["ms/search", "speedup", "imbalance", "tighten", "pruned%"],
    );

    // serial baseline row
    let summary = protocol.run(|| {
        let out = engine.search(&query, K, EXCLUSION).expect("search");
        assert_eq!(out.hits.len(), serial.hits.len());
    });
    let serial_ms = summary.mean_ms;
    table.row(
        "serial cascade",
        vec![
            format!("{:.2}", serial_ms),
            "1.00x".to_string(),
            "-".to_string(),
            "-".to_string(),
            format!("{:.1}", serial.stats.prune_fraction() * 100.0),
        ],
    );

    for shards in [1usize, 2, 4, 8] {
        let mut last: Option<ShardedOutcome> = None;
        let summary = protocol.run(|| {
            let out = engine
                .search_sharded(&query, K, EXCLUSION, CascadeOpts::default(), shards, shards)
                .expect("sharded search");
            last = Some(out);
        });
        let out = last.expect("at least one timed run");
        table.row(
            &format!("{shards} shard(s) × {shards} thread(s)"),
            vec![
                format!("{:.2}", summary.mean_ms),
                format!("{:.2}x", serial_ms / summary.mean_ms.max(1e-9)),
                out.imbalance()
                    .map(|r| format!("{r:.2}"))
                    .unwrap_or_else(|| "n/a".into()),
                format!("{}", out.tau_tightenings),
                format!("{:.1}", out.stats.prune_fraction() * 100.0),
            ],
        );
    }
    table.print();
    println!(
        "(speedup is vs the serial cascade; imbalance = slowest shard / mean shard \
         wall time; tighten = shared-τ decreases per search)"
    );
    Ok(())
}
