//! Bench: paper Table 1 — sDTW kernel + normalizer kernel average
//! throughput (Gsps, eq. 3) and execution time over the paper's protocol
//! (2 warm-up + 10 timed runs).
//!
//! Paper (AMD GPU, 512×2000 vs 100k):   sDTW 11036.5 ms, normalizer
//! 0.0214 ms.  This harness runs the scaled shape (DESIGN.md §4) on the
//! CPU-PJRT substitute and, with SDTW_BENCH_SLOW=1, the closest-to-paper
//! 64×500 vs 10k shape.  Compare *ratios*, not absolutes.
//!
//!   cargo bench --bench table1           # scaled shape
//!   SDTW_BENCH_SLOW=1 cargo bench --bench table1

use sdtw_repro::bench_harness::{banner, slow_benches_enabled, Table};
use sdtw_repro::experiments::{measure_variant, table1, Workload};
use sdtw_repro::runtime::artifact::Manifest;
use sdtw_repro::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::Path::new("artifacts");
    let protocol = banner("table1", "B=32 M=256 N=4096 (paper: 512x2000 vs 100k)");

    let table = table1(artifacts, 42, protocol)?;
    table.print();
    println!(
        "paper Table 1 (for ratio comparison): sDTW 11036.5 ms, normalizer 0.0214 ms;\n\
         note: the paper's printed Gsps values are inconsistent with its eq. 3 by ~10x\n\
         (EXPERIMENTS.md §Table-1) — we report eq. 3 as printed."
    );

    if slow_benches_enabled() {
        let manifest = Manifest::load(artifacts)?;
        let meta = manifest.require("sdtw_b64_m500_n10000_w25")?;
        let engine = Engine::start(manifest.clone())?;
        let wl = Workload::for_variant(meta, 42);
        let s = measure_variant(&engine.handle(), meta, &wl, protocol)?;
        let mut t = Table::new(
            "Table 1 (paper-μ shape, B=64 M=500 N=10000)",
            &["Gsps", "ms", "std ms"],
        );
        t.row(
            "sDTW kernel",
            vec![
                format!("{:.6}", s.gsps(wl.floats())),
                format!("{:.1}", s.mean_ms),
                format!("{:.1}", s.std_ms),
            ],
        );
        t.print();
    } else {
        println!("(SDTW_BENCH_SLOW=1 adds the 64×500 vs 10k paper-μ shape)");
    }
    Ok(())
}
