//! Ablation: band-constrained search — the Sakoe-Chiba radius swept from
//! unconstrained (band = 0, i.e. ∞) down through M/2, M/4, M/8.  Narrower
//! bands shrink both what the DP touches (|i-j| <= band cells per
//! survivor) and what the prefilter must bound (the banded envelope is
//! tighter), so ms/search should fall monotonically as the band narrows
//! while hits stay bit-identical to the *banded* brute force at the same
//! radius (the banded cascade is lossless w.r.t. its own semantics —
//! pruning never approximates).
//!
//!   cargo bench --bench banded_search
//!   SDTW_BENCH_QUICK=1 cargo bench --bench banded_search        # fast run
//!   SDTW_BENCH_JSON=out.jsonl ... cargo bench --bench banded_search
//!       # machine-readable rows for the CI bench lane (BENCH_ci.json)
//!
//! Workloads are the planted families from `search_cascade`: a drifting
//! walk and Cylinder-Bell-Funnel, both with warped copies of the query
//! planted — warps are modest, so even M/8 keeps the planted sites.

use std::sync::Arc;

use sdtw_repro::bench_harness::{banner, emit_json, Table};
use sdtw_repro::datagen::{planted_workload, Family};
use sdtw_repro::dtw::{sdtw_banded_anchored_into, Dist};
use sdtw_repro::normalize::znormed;
use sdtw_repro::search::{
    select_topk, CascadeOpts, CascadeStats, Hit, SearchEngine,
};
use sdtw_repro::util::json::Json;
use sdtw_repro::util::rng::Xoshiro256;

const REFLEN: usize = 8192;
const QLEN: usize = 128;
const WINDOW: usize = QLEN + QLEN / 2;
const K: usize = 6;
const EXCLUSION: usize = WINDOW / 2;
const PLANTS: usize = 6;
const SEED: u64 = 42;

fn workload(family: Family, seed: u64) -> (Arc<Vec<f32>>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let (reference, query, _) =
        planted_workload(family, REFLEN, QLEN, PLANTS, 0.05, &mut rng);
    (Arc::new(znormed(&reference)), znormed(&query))
}

/// The oracle at this radius: anchored banded DP on every candidate
/// window (band = 0 falls back to the unconstrained brute force, which
/// `CascadeOpts::BRUTE` already is).
fn banded_brute(engine: &SearchEngine, query: &[f32], band: usize) -> Vec<Hit> {
    if band == 0 {
        return engine
            .search_opts(query, K, EXCLUSION, CascadeOpts::BRUTE, 1)
            .expect("brute")
            .hits;
    }
    let index = engine.index();
    let (mut prev, mut cur) = (Vec::new(), Vec::new());
    let mut hits = Vec::new();
    for t in 0..index.candidates() {
        if let Some(m) = sdtw_banded_anchored_into(
            query,
            index.window_slice(t),
            band,
            f32::INFINITY,
            Dist::Sq,
            &mut prev,
            &mut cur,
        ) {
            let start = index.start(t);
            hits.push(Hit { start, end: start + m.end, cost: m.cost });
        }
    }
    select_topk(&hits, K, EXCLUSION)
}

fn main() -> anyhow::Result<()> {
    let protocol = banner(
        "banded_search",
        &format!("N={REFLEN} M={QLEN} window={WINDOW} K={K} exclusion={EXCLUSION} seed={SEED}"),
    );

    let configs: [(&str, usize); 4] = [
        ("band ∞ (off)", 0),
        ("band M/2", QLEN / 2),
        ("band M/4", QLEN / 4),
        ("band M/8", QLEN / 8),
    ];

    for family in [Family::Walk, Family::Cbf] {
        let (reference, query) = workload(family, SEED);
        let engine = SearchEngine::new(reference, WINDOW, 1, Dist::Sq)?;
        let candidates = engine.index().candidates();

        // correctness first: at every radius the cascade must reproduce
        // the banded brute force at the *same* radius, bit for bit
        for (label, band) in &configs {
            let opts = CascadeOpts::default().with_band(*band);
            let got = engine.search_opts(&query, K, EXCLUSION, opts, 1)?;
            let brute = banded_brute(&engine, &query, *band);
            assert_eq!(got.hits.len(), brute.len(), "{label}: hit count diverged");
            for (a, b) in got.hits.iter().zip(&brute) {
                assert_eq!(a.start, b.start, "{label}: start diverged");
                assert_eq!(a.end, b.end, "{label}: end diverged");
                assert_eq!(
                    a.cost.to_bits(),
                    b.cost.to_bits(),
                    "{label}: cost not bit-identical ({} vs {})",
                    a.cost,
                    b.cost
                );
            }
            let s = got.stats;
            assert_eq!(
                s.pruned_total() + s.dp_full,
                s.candidates,
                "{label}: counters must partition the candidate space"
            );
        }

        let mut table = Table::new(
            &format!("Sakoe-Chiba band ablation — {family:?} ({candidates} candidate windows)"),
            &["ms/search", "Mcand/s", "speedup", "pruned%", "cells_skipped"],
        );
        let mut unbanded_ms = 0.0f64;
        for (label, band) in &configs {
            let opts = CascadeOpts::default().with_band(*band);
            let mut stats = CascadeStats::default();
            let summary = protocol.run(|| {
                stats = engine
                    .search_opts(&query, K, EXCLUSION, opts, 1)
                    .expect("search")
                    .stats;
            });
            if unbanded_ms == 0.0 {
                unbanded_ms = summary.mean_ms;
            }
            let mcand_s = candidates as f64 / (summary.mean_ms * 1e3).max(1e-12);
            table.row(
                label,
                vec![
                    format!("{:.3}", summary.mean_ms),
                    format!("{:.2}", mcand_s),
                    format!("{:.2}x", unbanded_ms / summary.mean_ms.max(1e-9)),
                    format!("{:.1}", stats.prune_fraction() * 100.0),
                    format!("{}", stats.band_cells_skipped),
                ],
            );
            emit_json(
                "banded_search",
                vec![
                    ("family", Json::str(&format!("{family:?}"))),
                    ("config", Json::str(label)),
                    ("band", Json::Int(*band as i64)),
                    ("candidates", Json::Int(candidates as i64)),
                    ("ms_per_search", Json::Num(summary.mean_ms)),
                    ("mcand_per_s", Json::Num(mcand_s)),
                    ("speedup_vs_unbanded", Json::Num(unbanded_ms / summary.mean_ms.max(1e-9))),
                    ("prune_fraction", Json::Num(stats.prune_fraction())),
                    ("pruned_kim", Json::Int(stats.pruned_kim as i64)),
                    ("pruned_keogh", Json::Int(stats.pruned_keogh as i64)),
                    ("pruned_band", Json::Int(stats.pruned_band as i64)),
                    ("dp_abandoned", Json::Int(stats.dp_abandoned as i64)),
                    ("dp_full", Json::Int(stats.dp_full as i64)),
                    ("band_cells_skipped", Json::Int(stats.band_cells_skipped as i64)),
                    ("bit_identical", Json::Bool(true)),
                ],
            );
        }
        table.print();
    }
    println!(
        "\nnote: every radius above was asserted bit-identical to the banded \
         brute force at the same radius before timing; `sdtw search --band N` \
         serves the same configurations end-to-end."
    );
    Ok(())
}
